"""Closed-loop plan store: blend math, parity locks, re-estimation,
PlanCache persistence, and the real-executor observe path.

Deterministic twins of the hypothesis properties live here too (the
container may lack hypothesis; CI runs both).
"""

import json
import math
import warnings

import pytest

from repro.core import (AdaptivePlanStore, ConcurrencyRuntime,
                        CorrectionTable, CurveModel, GraphBuilder,
                        OpObservation, OBS_FINISH, OBS_LAUNCH, OBS_REVOKE,
                        PreemptionPolicy, RealGraphExecutor, RuntimeConfig,
                        SimMachine, build_paper_graph, make_plan_store)
from repro.core.perfmodel import cross_graph_key
from repro.multitenant import (JobQueue, PlanCache, PoolConfig, RuntimePool,
                               compare_timelines, corun_timeline,
                               pool_timeline, timeline_rows)


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


class OverpredictingMachine(SimMachine):
    """A profiling context uniformly 3x slower than the real machine —
    the stale-profile scenario the feedback loop corrects."""

    def op_time(self, op, placement, *, bw_share=1.0):
        return super().op_time(op, placement, bw_share=bw_share) * 3.0

    @property
    def fingerprint(self):
        return (*super().fingerprint, "x3")


def _chain(name, n, cls="X", shape=(32, 16, 16, 64)):
    b = GraphBuilder(name)
    prev = None
    for _ in range(n):
        prev = b.add(cls, shape, flops=4e8, bytes_moved=2e6,
                     deps=[prev] if prev is not None else [])
    return b.build()


# ---------------------------------------------------------------------------
# CorrectionTable blend math
# ---------------------------------------------------------------------------

class TestCorrectionTable:
    def test_incremental_ewma_moves_toward_ratio(self):
        t = CorrectionTable(alpha=0.25)
        t.update("k", 8, True, 2.0)
        assert t.factor("k", 8, True) == pytest.approx(1.25)
        t.update("k", 8, True, 2.0)
        assert t.factor("k", 8, True) == pytest.approx(1.4375)

    def test_converges_to_observed_ratio(self):
        t = CorrectionTable(alpha=0.25)
        for _ in range(40):
            t.update("k", 8, True, 0.5)
        assert t.factor("k", 8, True) == pytest.approx(0.5, rel=1e-3)

    def test_ratio_clamped_to_bounds(self):
        t = CorrectionTable(alpha=1.0)
        t.update("k", 8, True, 1e9)
        assert t.factor("k", 8, True) == t.ratio_bounds[1]
        t.update("k", 8, True, 0.0)
        assert t.factor("k", 8, True) == t.ratio_bounds[0]

    def test_exact_observations_are_exactly_stable(self):
        """The parity-critical property: ratio-1.0 observations leave the
        correction at EXACTLY 1.0 (no float drift), for any alpha."""
        for alpha in (0.25, 0.3, 0.1, 0.7):
            t = CorrectionTable(alpha=alpha)
            for _ in range(100):
                t.update("k", 8, True, 1.0)
            assert t.factor("k", 8, True) == 1.0

    def test_overall_key_fallback_for_unobserved_width(self):
        t = CorrectionTable(alpha=1.0)
        t.update("k", 8, True, 2.0)
        # exact point seen -> point correction; other width -> key-level
        assert t.factor("k", 8, True) == 2.0
        assert t.factor("k", 16, False) == 2.0
        assert t.factor("other", 8, True) == 1.0


# ---------------------------------------------------------------------------
# zero-error parity: feedback="ewma" on an exact trace == feedback="off"
# ---------------------------------------------------------------------------

class TestZeroErrorParity:
    @pytest.mark.parametrize("model", ["dcgan", "resnet50"])
    def test_corun_ewma_zero_error_bitwise_off(self, model):
        graph = build_paper_graph(model)
        off = corun_timeline(graph, SimMachine(seed=0))
        ew = corun_timeline(graph, SimMachine(seed=0),
                            RuntimeConfig(feedback="ewma"), zero_error=True)
        assert off.makespan == ew.makespan
        assert not compare_timelines(timeline_rows(off), timeline_rows(ew))

    @pytest.mark.parametrize("model", ["dcgan", "resnet50"])
    def test_pool_ewma_zero_error_bitwise_off(self, model):
        graph = build_paper_graph(model)
        off = corun_timeline(graph, SimMachine(seed=0))
        ew = pool_timeline(graph, SimMachine(seed=0),
                           RuntimeConfig(feedback="ewma"), zero_error=True)
        assert off.makespan == ew.makespan
        assert not compare_timelines(timeline_rows(off), timeline_rows(ew))

    def test_quadrant_topology_zero_error_parity(self):
        """The zero-error lock must hold under topology="quadrant" too —
        placement decisions consume the same predictions."""
        graph = build_paper_graph("dcgan")
        off = corun_timeline(graph, SimMachine(seed=0),
                             RuntimeConfig(topology="quadrant"))
        ew = pool_timeline(graph, SimMachine(seed=0),
                           RuntimeConfig(topology="quadrant",
                                         feedback="ewma"), zero_error=True)
        assert off.makespan == ew.makespan
        assert not compare_timelines(timeline_rows(off), timeline_rows(ew))

    def test_live_ewma_observations_do_diverge(self):
        """Control for the lock above: REAL observations (co-run durations
        vs solo predictions) must move corrections — otherwise the
        zero-error tests vouch for a feedback path that never fires."""
        graph = build_paper_graph("dcgan")
        rt = ConcurrencyRuntime(machine=SimMachine(seed=0),
                                config=RuntimeConfig(feedback="ewma"))
        rt.profile(graph)
        rt.execute_step(graph)
        corr = rt.planstore.corrections
        assert corr.observed > 0
        assert any(c != 1.0 for c in corr.point.values())


# ---------------------------------------------------------------------------
# adaptive prediction behavior
# ---------------------------------------------------------------------------

class TestAdaptivePrediction:
    def _store(self, machine):
        graph = _chain("g", 1)
        rt = ConcurrencyRuntime(machine=machine)
        rt.profile(graph)
        op = graph.ops[0]
        store = AdaptivePlanStore(rt.controller)
        return graph, op, store

    def _observe(self, store, op, threads, variant, factor, n=1):
        base = store.controller.store.curve(op).predict(threads, variant)
        for _ in range(n):
            store.observe(OpObservation(
                op=op, threads=threads, variant=variant, hyper=False,
                predicted=store.predict(op, threads, variant),
                observed=base * factor, kind=OBS_FINISH))
        return base

    def test_predictions_converge_to_observed_not_sqrt(self, machine):
        """The blend must divide by the BASE curve prediction: dividing
        by the (already-corrected) launch prediction converges to
        sqrt(ratio) — after many 2x observations the prediction must sit
        at ~2x base, well past sqrt(2)~1.41x."""
        _, op, store = self._store(machine)
        base = self._observe(store, op, 9, False, 2.0, n=30)
        assert store.predict(op, 9, False) == pytest.approx(2.0 * base,
                                                            rel=1e-3)

    def test_unobserved_width_uses_key_level_correction(self, machine):
        _, op, store = self._store(machine)
        self._observe(store, op, 9, False, 2.0, n=30)
        # a width never observed still benefits via the per-key fallback
        base17 = store.controller.store.curve(op).predict(17, False)
        assert store.predict(op, 17, False) == pytest.approx(2.0 * base17,
                                                             rel=1e-3)

    def test_candidates_reranked_by_corrections(self, machine):
        _, op, store = self._store(machine)
        frozen = store.controller.candidates_for(op, 3)
        best, runner = frozen[0], frozen[1]
        # the frozen best width observed 3x slower than profiled while the
        # runner-up runs 2x faster: per-width corrections must flip the
        # top seat (a single-width observation alone cannot — the per-key
        # fallback scales unobserved widths by the same factor)
        self._observe(store, op, best.threads, best.variant, 3.0, n=30)
        self._observe(store, op, runner.threads, runner.variant, 0.5, n=30)
        corrected = store.candidates(op, 3)
        assert corrected[0].threads == runner.threads
        assert {c.threads for c in corrected} <= \
            {t for v, pts in
             store.controller.store.curve(op).samples.items()
             for t, _ in pts}

    def test_launch_revoke_hyper_events_do_not_blend(self, machine):
        _, op, store = self._store(machine)
        pred = store.predict(op, 9, False)
        for kind, hyper in ((OBS_LAUNCH, False), (OBS_REVOKE, False),
                           (OBS_FINISH, True)):
            store.observe(OpObservation(
                op=op, threads=9, variant=False, hyper=hyper,
                predicted=pred, observed=pred * 7.0, kind=kind))
        assert store.corrections.observed == 0
        assert store.corrections.revoked == 1
        assert store.predict(op, 9, False) == pred

    def test_make_plan_store_rejects_unknown_mode(self, machine):
        _, op, store = self._store(machine)
        with pytest.raises(ValueError, match="unknown feedback mode"):
            make_plan_store("bogus", store.controller)


# ---------------------------------------------------------------------------
# online demand re-estimation (the admission currency)
# ---------------------------------------------------------------------------

class _AssertingQueue(JobQueue):
    """JobQueue that proves the admission-cap invariant at every pop:
    outstanding (live, possibly re-estimated) demand plus the admitted
    job's demand never exceeds the cap while the pool is busy."""

    def pop_admissible(self, active, now=float("inf")):
        job = super().pop_admissible(active, now)
        if (job is not None and self.max_outstanding_demand is not None
                and active):
            outstanding = sum(j.demand for j in active)
            assert outstanding + job.demand \
                <= self.max_outstanding_demand + 1e-9
        return job


class TestDemandReestimation:
    def _mix_pool(self, feedback, machine, **cfg):
        pool = RuntimePool(
            machine=machine, profile_machine=OverpredictingMachine(),
            config=PoolConfig(feedback=(feedback if feedback != "off"
                                        else None), **cfg))
        return pool

    def test_finished_jobs_have_zero_remaining_demand(self, machine):
        pool = self._mix_pool("ewma", machine, max_active=2)
        jobs = [pool.submit(_chain(f"j{i}", 4), name=f"j{i}")
                for i in range(2)]
        pool.run()
        for j in jobs:
            assert j.done and j.demand == 0.0

    def test_off_keeps_demand_frozen(self, machine):
        pool = self._mix_pool("off", machine, max_active=2)
        jobs = [pool.submit(_chain(f"j{i}", 4), name=f"j{i}")
                for i in range(2)]
        frozen = [j.demand for j in jobs]
        pool.run()
        assert [j.demand for j in jobs] == frozen
        assert all(d > 0 for d in frozen)

    def test_warm_corrections_reprice_admission_demand(self, machine):
        """A tenant submitted before any observations but ADMITTED after
        many must enter admission at corrected (here: ~1/3) demand — the
        frozen 3x-overpredicted estimate would hold the cap hostage."""
        pool = self._mix_pool("ewma", machine, max_active=1)
        first = pool.submit(_chain("warm", 8), name="warm")
        second = pool.submit(_chain("late", 8), name="late",
                             submit_time=1e-5)
        frozen_demand = second.demand
        pool.run()
        # by the time "late" was admitted, warm's 8 completions had
        # corrected the shared key: its priced demand must have dropped
        # toward ~1/3 of the frozen estimate (and its final is 0: done)
        assert first.done and second.done
        assert second.demand == 0.0
        assert frozen_demand > 0

    @pytest.mark.parametrize("feedback", ["off", "ewma"])
    def test_admission_cap_invariant_holds(self, machine, feedback):
        """Deterministic twin of the hypothesis property: with a demand
        cap and (for ewma) live re-estimation, every admission satisfies
        the cap with the demands in force at that instant."""
        pool = self._mix_pool(feedback, machine, max_active=3)
        pool.queue = _AssertingQueue(max_active=3)
        jobs = [pool.submit(_chain(f"j{i}", 3 + i), name=f"j{i}",
                            submit_time=i * 1e-4) for i in range(4)]
        pool.queue.max_outstanding_demand = 1.5 * max(j.demand for j in jobs)
        res = pool.run()
        assert all(j.done for j in jobs)
        assert res.total_ops == sum(j.graph.n_ops for j in jobs)


# ---------------------------------------------------------------------------
# frozen-Job.cp staleness regression (satellite: wrong preemption)
# ---------------------------------------------------------------------------

def _blocker_graph():
    b = GraphBuilder("blocker")
    b.add("Huge", (512, 512, 64), flops=8e9, bytes_moved=1e9,
          working_set=1e9)
    return b.build()


class TestCpStalenessRegression:
    """Profiles overpredict 3x.  A deadlined chain whose TRUE remaining
    work comfortably fits its budget gets priced at 3x under the frozen
    plan, so its slack goes (wrongly) negative while a long op runs —
    and the preemption path revokes that victim, paying restart waste
    for a deadline that was never in danger.  Under feedback="ewma" a
    warmup tenant's observations have already corrected the shared op
    key, the re-derived critical path prices the chain near truth,
    slack stays positive, and nobody is preempted — while the deadline
    is still met."""

    def _run(self, feedback):
        pool = RuntimePool(
            machine=SimMachine(),
            profile_machine=OverpredictingMachine(),
            config=PoolConfig(max_active=2,
                              feedback=(feedback if feedback != "off"
                                        else None),
                              preemption=PreemptionPolicy(enabled=True)))
        pool.submit(_chain("warm", 12), name="warm", submit_time=0.0)
        blocker = pool.submit(_blocker_graph(), name="blocker",
                              submit_time=0.014)
        dead = pool.submit(_chain("dead", 10), name="dead",
                           submit_time=0.016, deadline=0.016 + 0.028)
        res = pool.run()
        return res, blocker, dead

    def test_frozen_plan_preempts_wrongly(self):
        res, blocker, dead = self._run("off")
        assert res.n_preemptions >= 1, \
            "control: the frozen plan must trigger the wrong preemption"
        # ... even though the deadline never needed it
        assert dead.finish_time is not None
        assert dead.finish_time <= dead.deadline

    def test_ewma_avoids_wrong_preemption_and_meets_deadline(self):
        res_off, blk_off, _ = self._run("off")
        res_ew, blk_ew, dead = self._run("ewma")
        assert res_ew.n_preemptions == 0
        assert dead.finish_time is not None
        assert dead.finish_time <= dead.deadline
        # the spared victim finishes earlier than under the frozen plan
        # (no revoked partial run to re-pay)
        assert blk_ew.latency < blk_off.latency


# ---------------------------------------------------------------------------
# PlanCache persistence
# ---------------------------------------------------------------------------

class TestPlanCachePersistence:
    def _curve(self, scale=1.0):
        return CurveModel(
            samples={False: [(1, 0.9 * scale), (5, 0.31 * scale)],
                     True: [(2, 0.7 * scale), (10, 0.27 * scale)]},
            case_lists={False: [1, 2, 3, 4, 5], True: [2, 4, 6, 8, 10]},
            probes=4)

    def test_round_trip_preserves_curves_lru_and_stats(self, tmp_path):
        cache = PlanCache(max_entries=5, hits=7, misses=3, probes_saved=28,
                          evictions=2, probes_evicted=8)
        keys = [("Conv2D", (32, 8, 8, 64), 1e9, 2e6, 2e6, 0.96, True),
                ("MatMul", (16, 16), 4e8, 6e4, 6e4, 0.96, True),
                ("Sum", (8, 8), 1e6, 5e2, 5e2, 0.65, False)]
        for i, k in enumerate(keys):
            cache.insert(k, self._curve(scale=1.0 + i))
        cache.lookup(keys[0])            # refresh: LRU order now 1,2,0
        path = tmp_path / "cache.json"
        cache.dump(path)
        loaded = PlanCache.load(path)
        # internal keys are (namespace, key) pairs; unbound inserts live
        # under the None namespace
        assert list(loaded.curves) == [(None, keys[1]), (None, keys[2]),
                                       (None, keys[0])]
        for k in keys:
            a, b = cache.curves[(None, k)], loaded.curves[(None, k)]
            assert a.samples == b.samples          # bit-exact floats
            assert a.case_lists == b.case_lists
            assert a.probes == b.probes
        assert loaded.max_entries == 5
        # lookup() above mutated hits; stats must match the dumped state
        assert loaded.stats() == cache.stats()

    def test_loaded_recency_drives_eviction(self, tmp_path):
        cache = PlanCache(max_entries=2)
        cache.insert("a", self._curve())
        cache.insert("b", self._curve())
        cache.lookup("a")                # "b" is now the LRU entry
        path = tmp_path / "cache.json"
        cache.dump(path)
        loaded = PlanCache.load(path)
        loaded.insert("c", self._curve())
        assert set(loaded.curves) == {(None, "a"), (None, "c")}, \
            "persisted recency must decide who gets evicted"

    # degraded loads log through the shared "repro" logger (WARNING on
    # repro.multitenant.plancache), not warnings.warn — caplog asserts
    # both the level/logger and that the message names the fallback

    def test_corrupted_file_degrades_to_empty_with_warning(self, tmp_path,
                                                           caplog):
        path = tmp_path / "cache.json"
        path.write_text("{ this is not json")
        with caplog.at_level("WARNING", logger="repro.multitenant.plancache"):
            loaded = PlanCache.load(path)
        assert loaded.curves == {} and loaded.hits == 0
        assert any("falling back to an empty" in r.getMessage()
                   for r in caplog.records)

    def test_missing_file_degrades_to_empty_with_warning(self, tmp_path,
                                                         caplog):
        with caplog.at_level("WARNING", logger="repro.multitenant.plancache"):
            loaded = PlanCache.load(tmp_path / "nope.json")
        assert loaded.curves == {}
        assert any(r.name == "repro.multitenant.plancache"
                   and r.levelname == "WARNING"
                   and "falling back to an empty" in r.getMessage()
                   for r in caplog.records)

    def test_version_mismatch_degrades_to_empty_with_warning(self, tmp_path,
                                                             caplog):
        cache = PlanCache()
        cache.insert("a", self._curve())
        path = tmp_path / "cache.json"
        cache.dump(path)
        payload = json.loads(path.read_text())
        payload["schema"] = 999
        path.write_text(json.dumps(payload))
        with caplog.at_level("WARNING", logger="repro.multitenant.plancache"):
            loaded = PlanCache.load(path)
        assert loaded.curves == {}
        assert any("schema version" in r.getMessage() for r in caplog.records)

    def test_fingerprint_keyed_lookups_isolate_machines(self, tmp_path):
        """Regression (issue 10): binding used to be whole-cache and only
        compared at dump/load — lookups were never actually namespaced,
        so a heterogeneous cluster could not share one cache file.  Now
        every entry is keyed by the fingerprint bound at insert time."""
        fp_a = (SimMachine(seed=0).fingerprint, 4)
        fp_b = (SimMachine(seed=1).fingerprint, 4)
        cache = PlanCache()
        key = ("Conv2D", (32, 8, 8, 64), 1e9, 2e6, 2e6, 0.96, True)
        cache.bind_machine(fp_a)
        curve_a = self._curve(scale=1.0)
        cache.insert(key, curve_a)
        # machine B must NOT see machine A's curve for the same op key
        cache.bind_machine(fp_b)
        assert cache.lookup(key) is None
        curve_b = self._curve(scale=2.0)
        cache.insert(key, curve_b)
        # each machine reuses exactly its own curve
        assert cache.lookup(key).samples == curve_b.samples
        cache.bind_machine(fp_a)
        assert cache.lookup(key).samples == curve_a.samples
        assert cache.warm_keys(fp_a) == {key} == cache.warm_keys(fp_b)

        # one shared FILE round-trips both namespaces disjointly
        path = tmp_path / "cache.json"
        cache.dump(path)
        loaded = PlanCache.load(path)
        loaded.bind_machine((SimMachine(seed=0).fingerprint, 4))
        assert loaded.lookup(key).samples == curve_a.samples
        loaded.bind_machine((SimMachine(seed=1).fingerprint, 4))
        assert loaded.lookup(key).samples == curve_b.samples
        # ...and a context never written to the file stays cold
        loaded.bind_machine((SimMachine(seed=0).fingerprint, 8))
        assert loaded.lookup(key) is None

    def test_legacy_schema1_file_loads_under_its_fingerprint(self, tmp_path):
        fp = (SimMachine(seed=0).fingerprint, 4)
        cache = PlanCache()
        cache.bind_machine(fp)
        key = ("MatMul", (16, 16), 4e8, 6e4, 6e4, 0.96, True)
        cache.insert(key, self._curve())
        path = tmp_path / "cache.json"
        cache.dump(path)
        # rewrite as a v1 file: no per-entry namespace, whole-cache
        # fingerprint at top level
        payload = json.loads(path.read_text())
        payload["schema"] = 1
        for entry in payload["entries"]:
            del entry["ns"]
        path.write_text(json.dumps(payload))
        loaded = PlanCache.load(path)
        loaded.bind_machine((SimMachine(seed=0).fingerprint, 4))
        assert loaded.lookup(key) is not None, \
            "v1 entries belong to the file's whole-cache fingerprint"

    def test_pool_reuses_persisted_curves_without_probes(self, tmp_path,
                                                         machine):
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=2))
        pool.submit(build_paper_graph("dcgan"), name="a")
        pool.run()
        path = tmp_path / "cache.json"
        pool.plan_cache.dump(path)
        spent_before = pool.plan_cache.probes_spent

        loaded = PlanCache.load(path)
        pool2 = RuntimePool(machine=SimMachine(), plan_cache=loaded,
                            config=PoolConfig(max_active=2))
        pool2.submit(build_paper_graph("dcgan"), name="b")
        res = pool2.run()
        assert loaded.probes_spent == spent_before, \
            "a warm persisted cache must pay zero new probes"
        assert res.cache_stats["probes_saved"] > 0


# ---------------------------------------------------------------------------
# real-payload executor feeds the same observe API
# ---------------------------------------------------------------------------

class TestRealExecutorObserve:
    def test_payload_timings_flow_into_store(self, machine):
        b = GraphBuilder("real")
        u0 = b.add("X", (32, 16, 16, 64), flops=4e8, bytes_moved=2e6,
                   payload=lambda deps: sum(range(1000)))
        b.add("X", (32, 16, 16, 64), flops=4e8, bytes_moved=2e6,
              deps=[u0], payload=lambda deps: deps[u0] + 1)
        graph = b.build()
        rt = ConcurrencyRuntime(machine=machine,
                                config=RuntimeConfig(feedback="ewma"))
        rt.profile(graph)
        store = rt.planstore
        results, timings, wall = RealGraphExecutor(max_workers=2).run(
            graph, store=store, plan=rt.plan)
        assert len(timings) == graph.n_ops
        assert store.corrections.observed == graph.n_ops
        # the wall-clock observations landed on the ops' curve key
        key = cross_graph_key(graph.ops[0])
        assert store.corrections.overall.get(key) is not None

    def test_executor_without_store_unchanged(self):
        b = GraphBuilder("real")
        b.add("X", (8, 8), flops=1e6, bytes_moved=1e3,
              payload=lambda deps: 42)
        results, timings, wall = RealGraphExecutor().run(b.build())
        assert results[0] == 42 and 0 in timings
