"""Pool-as-a-service: the JobSpec wire schema, config serialization,
atomic persistence, the pool lifecycle split (begin/step/result, cancel,
observer seam), the daemon's file protocol, and crash recovery.
"""

import dataclasses
import json
import os
import pathlib
import shutil
import subprocess
import sys
import warnings

import pytest

from repro.core import (GraphBuilder, RuntimeConfig, SimMachine,
                        build_paper_graph)
from repro.core.strategy import (CONFIG_SCHEMA_VERSION, PreemptionPolicy,
                                 StrategyConfig)
from repro.multitenant import PlanCache, PoolConfig, RuntimePool
from repro.multitenant.plancache import atomic_write_text
from repro.multitenant.pool import PoolObserver
from repro.obs import RecordingSink
from repro.obs.trace import FAM_SERVICE
from repro.service import (ATTACHED_GRAPH, JobEntry, JobSpec, PoolDaemon,
                           StoreState, load_store, save_store, submit_spec)
from repro.launch.service import enqueue_command, read_reply


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


# ---------------------------------------------------------------------------
# JobSpec: the one submission wire schema
# ---------------------------------------------------------------------------

class TestJobSpec:
    def test_round_trip(self):
        spec = JobSpec(workload="rnn", name="r0", priority=2.0,
                       submit_time=0.5, latency_budget=1.0, trips=5)
        again = JobSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert again == spec

    def test_deadline_xor_budget(self):
        with pytest.raises(ValueError, match="deadline"):
            JobSpec(workload="resnet50", deadline=1.0, latency_budget=1.0)

    def test_resolved_deadline(self):
        assert JobSpec(workload="dcgan", submit_time=2.0,
                       latency_budget=1.5).resolved_deadline() == 3.5
        assert JobSpec(workload="dcgan", deadline=4.0) \
            .resolved_deadline() == 4.0
        assert JobSpec(workload="dcgan").resolved_deadline() is None

    def test_unknown_key_rejected(self):
        d = JobSpec(workload="dcgan").to_dict()
        d["thread_count"] = 4
        with pytest.raises(ValueError, match="thread_count"):
            JobSpec.from_dict(d)

    def test_schema_version_checked(self):
        d = JobSpec(workload="dcgan").to_dict()
        d["schema"] = 999
        with pytest.raises(ValueError, match="schema"):
            JobSpec.from_dict(d)

    def test_build_graph_variants(self):
        assert JobSpec(workload="resnet50").build_graph().n_ops \
            == build_paper_graph("resnet50").n_ops
        rnn = JobSpec(workload="rnn", trips=5, max_trips=8).build_graph()
        wave = JobSpec(workload="wave", depth=2).build_graph()
        assert rnn.regions and wave.regions
        with pytest.raises(ValueError, match="in-process graph"):
            JobSpec(workload=ATTACHED_GRAPH).build_graph()

    def test_demand_hint_overrides_profiled_demand(self, machine):
        pool = RuntimePool(machine=machine)
        job = submit_spec(pool, JobSpec(workload="dcgan",
                                        demand_hint=123.0))
        assert job.demand == 123.0

    def test_attached_graph_submit(self, machine):
        pool = RuntimePool(machine=machine)
        g = build_paper_graph("dcgan")
        job = submit_spec(pool, JobSpec(workload=ATTACHED_GRAPH,
                                        name="att"), graph=g)
        assert job.graph is g and job.name == "att"
        with pytest.raises(ValueError):
            submit_spec(pool, JobSpec(workload=ATTACHED_GRAPH))


# ---------------------------------------------------------------------------
# config: one source of truth, serializable, back-compatible
# ---------------------------------------------------------------------------

class TestConfigSerialization:
    def test_strategy_round_trip(self):
        s = StrategyConfig(candidates=5, feedback="ewma",
                           preemption=PreemptionPolicy(enabled=True,
                                                       max_victims=2))
        again = StrategyConfig.from_dict(json.loads(json.dumps(s.to_dict())))
        assert again == s

    def test_runtime_round_trip(self):
        c = RuntimeConfig(interval=8, strategy=StrategyConfig(topology="quadrant"))
        again = RuntimeConfig.from_dict(c.to_dict())
        assert again.interval == 8
        assert again.strategy == c.strategy

    def test_pool_round_trip(self):
        c = PoolConfig(max_active=5,
                       runtime=RuntimeConfig(
                           strategy=StrategyConfig(feedback="ewma")),
                       strategy=StrategyConfig(candidates=2))
        again = PoolConfig.from_dict(json.loads(json.dumps(c.to_dict())))
        assert again.max_active == 5
        assert again.strategy_config() == c.strategy_config()
        assert again.runtime.strategy == c.runtime.strategy

    def test_sink_not_serialized(self):
        c = PoolConfig(strategy=StrategyConfig(sink=RecordingSink()))
        d = json.loads(json.dumps(c.to_dict()))   # must be JSON-clean
        assert "sink" not in d["strategy"]

    def test_unknown_key_rejected(self):
        d = RuntimeConfig().to_dict()
        d["stratgy"] = {}
        with pytest.raises(ValueError, match="stratgy"):
            RuntimeConfig.from_dict(d)

    def test_deprecated_kwargs_warn_and_work(self):
        with pytest.warns(DeprecationWarning, match="StrategyConfig"):
            c = RuntimeConfig(feedback="ewma", candidates=5)
        assert c.feedback == "ewma" and c.candidates == 5
        with pytest.warns(DeprecationWarning):
            p = PoolConfig(max_active=2, topology="quadrant")
        assert p.strategy_config().topology == "quadrant"
        with pytest.raises(TypeError, match="no_such_knob"):
            RuntimeConfig(no_such_knob=1)

    def test_replace_applies_on_top_of_strategy(self):
        base = RuntimeConfig(strategy=StrategyConfig(candidates=7))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            fb = dataclasses.replace(base, feedback="ewma")
        assert fb.feedback == "ewma" and fb.candidates == 7


# ---------------------------------------------------------------------------
# atomic persistence
# ---------------------------------------------------------------------------

class TestAtomicWrites:
    def test_truncated_tempfile_never_shadows_cache(self, machine,
                                                    tmp_path):
        path = tmp_path / "cache.json"
        pool = RuntimePool(machine=machine)
        pool.submit(build_paper_graph("dcgan"))
        pool.run()
        pool.plan_cache.dump(path)
        good = path.read_text()

        # a crashed writer leaves only its temp file behind; the real
        # cache file must be byte-identical to the last good dump and
        # stray temp files must never be picked up by load()
        (tmp_path / "cache.json.deadbeef.tmp").write_text(
            good[:len(good) // 2])
        assert path.read_text() == good
        loaded = PlanCache.load(path)
        assert loaded.stats()["curves"] == pool.plan_cache.stats()["curves"]

    def test_atomic_write_failure_keeps_previous(self, tmp_path):
        path = tmp_path / "f.json"
        atomic_write_text(path, "good")
        with pytest.raises(TypeError):
            atomic_write_text(path, object())  # not a str: write fails
        assert path.read_text() == "good"
        assert list(tmp_path.glob("*.tmp")) == []   # temp cleaned up


# ---------------------------------------------------------------------------
# job store
# ---------------------------------------------------------------------------

class TestJobStore:
    def test_round_trip(self, tmp_path):
        state = StoreState(
            clock=1.5, restarts=2, config=PoolConfig().to_dict(),
            entries=[JobEntry(spec=JobSpec(workload="rnn", trips=3),
                              order=0, state="running",
                              carried_waste=0.25, progress_core_s=1.0),
                     JobEntry(spec=JobSpec(workload="dcgan"), order=1,
                              state="done", result={"latency_s": 2.0})],
            corrections={"alpha": 0.4, "ratio_bounds": [0.25, 4.0],
                         "zero_error": False, "point": [], "overall": [],
                         "observed": 3, "revoked": 0})
        path = tmp_path / "store.json"
        save_store(path, state)
        again = load_store(path)
        assert again.clock == 1.5 and again.restarts == 2
        assert [e.order for e in again.entries] == [0, 1]
        assert again.entries[0].spec == state.entries[0].spec
        assert again.entries[0].progress_core_s == 1.0
        assert again.entries[1].result == {"latency_s": 2.0}
        assert again.corrections["observed"] == 3

    def test_missing_is_fresh_corrupt_warns(self, tmp_path):
        assert load_store(tmp_path / "absent.json") is None
        bad = tmp_path / "store.json"
        bad.write_text("{not json")
        with pytest.warns(UserWarning, match="starting fresh"):
            assert load_store(bad) is None

    def test_bad_entry_state_rejected(self, tmp_path):
        state = StoreState(entries=[JobEntry(
            spec=JobSpec(workload="dcgan"), order=0)])
        d = state.to_dict()
        d["entries"][0]["state"] = "exploded"
        path = tmp_path / "store.json"
        atomic_write_text(path, json.dumps(d))
        with pytest.warns(UserWarning, match="starting fresh"):
            assert load_store(path) is None


# ---------------------------------------------------------------------------
# pool lifecycle split: begin/step/result, mid-run submit, cancel
# ---------------------------------------------------------------------------

class TestPoolLifecycle:
    def _mix(self, pool):
        a = pool.submit(build_paper_graph("resnet50"))
        b = pool.submit(build_paper_graph("dcgan"), priority=2.0)
        return a, b

    def test_stepwise_equals_run(self, machine):
        p1 = RuntimePool(machine=machine, config=PoolConfig(max_active=2))
        self._mix(p1)
        ref = p1.run()
        p2 = RuntimePool(machine=machine, config=PoolConfig(max_active=2))
        self._mix(p2)
        p2.begin()
        while p2.step():
            pass
        res = p2.result()
        assert res.makespan == ref.makespan
        assert res.metrics == ref.metrics

    def test_submit_after_begin_is_admitted(self, machine):
        pool = RuntimePool(machine=machine)
        pool.begin()
        assert pool.step() is False          # idle daemon
        job = pool.submit(build_paper_graph("dcgan"))
        assert job.admit_time is not None    # admitted at submission
        while pool.step():
            pass
        assert job.done

    def test_run_resets_lifecycle(self, machine):
        pool = RuntimePool(machine=machine)
        pool.submit(build_paper_graph("dcgan"))
        pool.run()
        # a post-run submit must queue normally, not touch the dead sim
        job = pool.submit(build_paper_graph("dcgan"))
        assert job.admit_time is None

    def test_cancel_queued(self, machine):
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=1))
        a, b = self._mix(pool)      # b outranks a ... but a's first
        pool.begin()                # priority admits b, queues a
        assert pool.cancel(a.jid) is True
        assert a.cancelled and not a.done
        res_jobs = [j for j in pool.jobs if not j.cancelled]
        while pool.step():
            pass
        assert all(j.done for j in res_jobs)
        assert not a.done and a.admit_time is None

    def test_cancel_running_revokes_and_frees_slot(self, machine):
        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=1))
        a, b = self._mix(pool)
        pool.begin()
        pool.step()                  # launch something of b (admitted)
        assert pool.cancel(b.jid) is True
        while pool.step():
            pass
        assert a.done                # the freed slot admitted a
        assert b.cancelled and not b.done

    def test_cancel_terminal_is_false(self, machine):
        pool = RuntimePool(machine=machine)
        a, b = self._mix(pool)
        pool.run()
        assert pool.cancel(a.jid) is False      # done
        assert pool.cancel(999) is False        # unknown
        pool2 = RuntimePool(machine=machine,
                            config=PoolConfig(max_active=1))
        c, d = self._mix(pool2)
        pool2.begin()
        assert pool2.cancel(c.jid)
        assert pool2.cancel(c.jid) is False     # already cancelled


class _CountingObserver(PoolObserver):
    def __init__(self):
        self.launches, self.revokes, self.completes = [], [], []

    def on_launch(self, key, sched):
        self.launches.append(key)

    def on_revoke(self, key, sched):
        self.revokes.append(key)

    def on_complete(self, key, sched):
        self.completes.append(key)


class TestPoolObserver:
    def test_observer_mirrors_sim_and_stays_inert(self, machine):
        ref_pool = RuntimePool(machine=machine,
                               config=PoolConfig(max_active=2))
        ref_pool.submit(build_paper_graph("resnet50"))
        ref_pool.submit(build_paper_graph("dcgan"))
        ref = ref_pool.run()

        pool = RuntimePool(machine=machine, config=PoolConfig(max_active=2))
        pool.submit(build_paper_graph("resnet50"))
        pool.submit(build_paper_graph("dcgan"))
        obs = _CountingObserver()
        pool.observer = obs
        res = pool.run()
        assert res.makespan == ref.makespan     # observer is read-only
        assert len(obs.completes) == res.total_ops
        assert len(obs.launches) == res.total_ops + len(obs.revokes)


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------

@pytest.fixture()
def seeded_machine():
    return SimMachine(seed=7)


class TestPoolDaemon:
    def test_submit_status_cancel_drain(self, tmp_path, seeded_machine):
        daemon = PoolDaemon(tmp_path, machine=seeded_machine,
                            config=PoolConfig(max_active=2))
        ids = [daemon.submit(JobSpec(workload="resnet50", name="r0")),
               daemon.submit(JobSpec(workload="dcgan", name="d1")),
               daemon.submit(JobSpec(workload="dcgan", name="d2"))]
        assert ids == ["job-0", "job-1", "job-2"]
        st = daemon.status()
        assert {j["id"]: j["state"] for j in st["jobs"]} == {
            "job-0": "admitted", "job-1": "admitted", "job-2": "queued"}
        assert daemon.cancel("job-2") is True
        assert daemon.cancel("job-9") is False
        res = daemon.drain()
        daemon.close()
        st = daemon.status()
        states = {j["id"]: j["state"] for j in st["jobs"]}
        assert states == {"job-0": "done", "job-1": "done",
                          "job-2": "cancelled"}
        assert st["jobs"][0]["result"]["latency_s"] is not None

        # bit-for-bit the equivalent direct library run (same
        # submissions, same pre-run cancellation)
        pool = RuntimePool(machine=SimMachine(seed=7),
                           config=PoolConfig(max_active=2))
        jobs = [submit_spec(pool, JobSpec(workload="resnet50", name="r0")),
                submit_spec(pool, JobSpec(workload="dcgan", name="d1")),
                submit_spec(pool, JobSpec(workload="dcgan", name="d2"))]
        pool.cancel(jobs[2].jid)
        ref = pool.run()
        assert res.makespan == ref.makespan
        assert res.metrics == ref.metrics

    def test_daemon_executes_payloads(self, tmp_path, seeded_machine):
        b = GraphBuilder("real")
        u0 = b.add("X", (32, 16, 16, 64), flops=4e8, bytes_moved=2e6,
                   payload=lambda deps: 21)
        b.add("X", (32, 16, 16, 64), flops=4e8, bytes_moved=2e6,
              deps=[u0], payload=lambda deps: deps[u0] * 2)
        daemon = PoolDaemon(tmp_path, machine=seeded_machine)
        daemon.submit(JobSpec(workload=ATTACHED_GRAPH, name="real"),
                      graph=b.build())
        daemon.drain()
        jid = daemon.pool.jobs[0].jid
        futs = daemon.observer.futures[jid]
        assert futs[1].result()[0] == 42    # dep value flowed through
        daemon.close()

    def test_service_trace_events(self, tmp_path, seeded_machine):
        sink = RecordingSink()
        cfg = PoolConfig(max_active=2,
                         strategy=StrategyConfig(sink=sink))
        daemon = PoolDaemon(tmp_path, machine=seeded_machine, config=cfg)
        daemon.submit(JobSpec(workload="dcgan"))
        daemon.drain()
        daemon.close()
        kinds = {e.kind for e in sink.events if e.family == FAM_SERVICE}
        assert {"start", "submit", "checkpoint", "drain",
                "stop"} <= kinds


class TestFileProtocol:
    def test_inbox_round_trip_once_mode(self, tmp_path, seeded_machine):
        specs = [JobSpec(workload="resnet50"), JobSpec(workload="dcgan")]
        replies = [enqueue_command(
            tmp_path, {"op": "submit", "spec": s.to_dict()}, seq=i)
            for i, s in enumerate(specs)]
        replies.append(enqueue_command(tmp_path, {"op": "status"}, seq=2))
        replies.append(enqueue_command(
            tmp_path, {"op": "cancel", "job": "job-1"}, seq=3))
        replies.append(enqueue_command(tmp_path, {"op": "drain"}, seq=4))
        daemon = PoolDaemon(tmp_path, machine=seeded_machine,
                            config=PoolConfig(max_active=1))
        daemon.serve(once=True)         # consumes the inbox, drains, exits
        out = [read_reply(p, timeout=1.0) for p in replies]
        assert all(r["ok"] for r in out)
        assert out[0]["job"] == "job-0" and out[1]["job"] == "job-1"
        assert out[4]["metrics"]["pool.total_ops"] > 0
        assert list(tmp_path.glob("inbox/*.json")) == []

    def test_malformed_command_gets_error_reply(self, tmp_path,
                                                seeded_machine):
        bad = enqueue_command(tmp_path, {"op": "explode"}, seq=0)
        worse_path = tmp_path / "inbox" / f"{1:020d}-x-none.json"
        worse_path.write_text("{not json")
        stop = enqueue_command(tmp_path, {"op": "stop"}, seq=2)
        daemon = PoolDaemon(tmp_path, machine=seeded_machine)
        daemon.serve(once=True)
        assert read_reply(bad, timeout=1.0)["ok"] is False
        worse = read_reply(tmp_path / "outbox" / worse_path.name,
                           timeout=1.0)
        assert worse["ok"] is False and "error" in worse
        assert read_reply(stop, timeout=1.0)["ok"] is True


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def _ewma_config(max_active=2):
    return PoolConfig(max_active=max_active,
                      runtime=RuntimeConfig(
                          strategy=StrategyConfig(feedback="ewma")))


class TestCrashRecovery:
    def test_kill_and_restart_recovers_world(self, tmp_path):
        daemon = PoolDaemon(tmp_path, machine=SimMachine(seed=3),
                            config=_ewma_config())
        daemon.submit(JobSpec(workload="rnn", name="loop", trips=3,
                              max_trips=6))
        daemon.submit(JobSpec(workload="resnet50", name="cnn"))
        daemon.submit(JobSpec(workload="dcgan", name="gan0"))
        daemon.submit(JobSpec(workload="dcgan", name="gan1"))
        # pump until the crash preconditions hold: >=1 admission with
        # launches, >=1 ewma correction, >=1 learned trip count, and at
        # least one job still queued (the mid-mix kill point)
        for _ in range(3000):
            if (daemon.pool.corrections.observed >= 1
                    and daemon.pool.trip_counts.observed >= 1
                    and len(daemon.pool.queue) >= 1):
                break
            if not daemon.pump(1):
                pytest.fail("mix drained before crash preconditions held")
        corr_before = daemon.pool.corrections.observed
        trips_before = daemon.pool.trip_counts.observed
        probes_before = daemon.pool.plan_cache.probes_spent
        hits_before = daemon.pool.plan_cache.hits
        queued_names = [j.name for j in daemon.pool.queue.waiting_jobs()]
        started_orders = [e.order for e in daemon.entries
                          if e.progress_core_s > 0]
        assert started_orders, "no launched work at the kill point"
        # simulated hard crash: no close(), no final checkpoint — the
        # restarted daemon sees only what per-step checkpoints persisted

        d2 = PoolDaemon(tmp_path, machine=SimMachine(seed=3))
        assert d2.restarts == 1
        # config recovered from the store (feedback stayed armed)
        assert d2.pool.feedback == "ewma"
        # learned state carried over, counts do NOT reset
        assert d2.pool.corrections.observed == corr_before
        assert d2.pool.trip_counts.observed == trips_before
        # warm plan cache: recovery profiling pays ZERO new probes (the
        # persisted probe count does not reset and does not grow) and is
        # served from cache hits
        assert d2.pool.plan_cache.probes_spent == probes_before
        assert d2.pool.plan_cache.hits > hits_before
        # unfinished jobs re-queued/readmitted in original submit order
        recovered = [e for e in d2.entries
                     if e.state not in ("done", "cancelled")]
        assert [e.order for e in recovered] == sorted(
            e.order for e in recovered)
        assert [j.name for j in d2.pool.queue.waiting_jobs()] \
            == queued_names
        # interrupted work re-billed as restart waste, exactly once
        billed = {e.order: e.carried_waste for e in d2.entries}
        waste_factor = d2.pool.machine.spec.restart_waste
        for e in d2.entries:
            if e.order in started_orders:
                assert e.carried_waste > 0
            else:
                assert e.carried_waste == 0.0
        # a second crash with no progress re-bills NOTHING
        d3 = PoolDaemon(tmp_path, machine=SimMachine(seed=3))
        assert d3.restarts == 2
        assert {e.order: e.carried_waste for e in d3.entries} == billed
        assert waste_factor > 0     # the billing above wasn't vacuous

        res = d3.drain()
        d3.close()
        states = {j["id"]: j["state"] for j in d3.status()["jobs"]}
        assert set(states.values()) == {"done"}
        assert res.makespan > 0

    def test_done_jobs_survive_as_history(self, tmp_path):
        daemon = PoolDaemon(tmp_path, machine=SimMachine(seed=3))
        daemon.submit(JobSpec(workload="dcgan", name="d0"))
        daemon.drain()
        latency = daemon.status()["jobs"][0]["result"]["latency_s"]
        d2 = PoolDaemon(tmp_path, machine=SimMachine(seed=3))
        st = d2.status()["jobs"][0]
        assert st["state"] == "done"
        assert st["result"]["latency_s"] == latency
        # done jobs are history, not resubmitted
        assert len(d2.pool.jobs) == 0
        # and the next submission gets a FRESH ticket
        assert d2.submit(JobSpec(workload="dcgan", name="d1")) == "job-1"


ARTIFACT_DIR = pathlib.Path(__file__).parent.parent / "test-artifacts"


@pytest.mark.slow
class TestCrashRecoverySubprocess:
    def test_crash_after_steps_and_restart(self, tmp_path):
        env = {**os.environ, "PYTHONPATH": "src"}
        state = tmp_path / "state"
        try:
            for i, wl in enumerate(("resnet50", "dcgan")):
                enqueue_command(
                    state, {"op": "submit",
                            "spec": JobSpec(workload=wl).to_dict()}, seq=i)
            crash = subprocess.run(
                [sys.executable, "-m", "repro.launch.service", "start",
                 "--state-dir", str(state), "--feedback", "ewma",
                 "--crash-after-steps", "4"],
                env=env, capture_output=True, text=True, timeout=120)
            assert crash.returncode == 1, crash.stderr
            store = load_store(state / "store.json")
            assert store is not None and store.clock > 0

            enqueue_command(state, {"op": "drain"}, seq=10)
            restart = subprocess.run(
                [sys.executable, "-m", "repro.launch.service", "start",
                 "--state-dir", str(state), "--once"],
                env=env, capture_output=True, text=True, timeout=120)
            assert restart.returncode == 0, restart.stderr
            store = load_store(state / "store.json")
            assert store.restarts == 1
            assert all(e.state == "done" for e in store.entries)
            assert any(e.carried_waste > 0 for e in store.entries)
        except Exception:
            # leave the job store for CI to upload as a failure artifact
            if state.is_dir():
                ARTIFACT_DIR.mkdir(exist_ok=True)
                dest = ARTIFACT_DIR / "service-recovery-state"
                shutil.rmtree(dest, ignore_errors=True)
                shutil.copytree(state, dest)
            raise

    def test_cli_smoke(self):
        env = {**os.environ, "PYTHONPATH": "src"}
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.service", "smoke"],
            env=env, capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
