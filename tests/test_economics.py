"""Preemption economics: priced multi-victim revoke, free admission-level
eviction, and priced width migration (``PreemptionPolicy.max_victims`` /
``evict_admitted`` / ``migration``).

Two layers of coverage:

* **Deterministic scenario twins** — each economics move is driven through
  a pinned tenant mix run twice (move off / move on) on the same machine,
  so the assertions are about the *economics*: the move fires, it is
  priced (traced gain strictly exceeds traced cost), it helps the overdue
  tenant, and the usual pool invariants (exactly-once completion, no core
  oversubscription, exact service accounting) survive it.
* **Stub-adapter unit regressions** — core rules the pool mixes cannot pin
  deterministically (victim tie-breaks, the hyper-lane clamp re-predict,
  the quadrant fallback's next-biggest retry) are exercised against a
  table-driven ``StrategyAdapter``.

The armed-but-untriggered twin (economics knobs ON, no deadlines anywhere
-> bitwise the single-victim pool) plus the ``check_parity`` pool-preempt
leg are the behavior lock: the whole economics surface must be inert
unless armed AND triggered.
"""

import math

import pytest

from repro.core import (GraphBuilder, Op, OpPlan, PreemptionPolicy,
                        RuntimeConfig, SimMachine)
from repro.core.placement import REL_CROSS
from repro.core.strategy import (ScheduledOp, StrategyAdapter, StrategyConfig,
                                 StrategyCore)
from repro.multitenant import (Job, JobQueue, PoolConfig, PoolResult,
                               RuntimePool, compare_timelines, timeline_rows)
from repro.obs import RecordingSink


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


# ---------------------------------------------------------------------------
# scenario graphs (widths pinned by the profiler: the comments give the
# frozen plan each shape profiles to on the default SimMachine)
# ---------------------------------------------------------------------------

def _chain(name, cls, shape, flops, bm, ws, pf, n):
    b = GraphBuilder(name)
    prev = None
    for _ in range(n):
        prev = b.add(cls, shape, flops=flops, bytes_moved=bm,
                     working_set=ws, parallel_fraction=pf,
                     deps=[prev] if prev is not None else [])
    return b.build()


def _narrow_runner(n=2, flops=8e11):
    """Profiles to 17 threads, ~2.7s per op at flops=8e11 — four of these
    tile the 68-core machine exactly, leaving zero idle cores."""
    return _chain("runner", "RunnerOp", (48, 96, 64), flops, 4e7, 4e7,
                  0.96, n)


def _wide_chain(n=2, flops=4e11):
    """Profiles to the full 68 threads, ~0.28s per op — the wide deadlined
    tenant whose preferred width no single narrow victim can seat."""
    return _chain("wide", "WideStep", (256, 256, 64), flops, 5e7, 5e7,
                  0.99, n)


def _giant_op():
    """One 68-thread ~2.8s op: long enough that a squeezed launch is still
    running when the narrow runners drain — the migration window."""
    return _chain("giant", "GiantStep", (256, 256, 64), 4e12, 5e7, 5e7,
                  0.99, 1)


def _blocker(n=2):
    """~66-thread ~2.9s ops — fills the machine so a co-admitted narrow
    tenant sits idle (the admission-eviction victim)."""
    return _chain("blocker", "Huge", (512, 512, 64), 1e12, 1e9, 1e9,
                  0.9, n)


def _assert_exactly_once(res, jobs):
    for job in jobs:
        recs = res.records[job.jid]
        assert len(recs) == job.graph.n_ops
        assert len({r.op.uid for r in recs}) == job.graph.n_ops
        assert job.done


def _assert_no_oversubscription(machine, res):
    spans = [(r.start, r.finish, r.threads)
             for recs in res.records.values() for r in recs if not r.hyper]
    spans += [(p.start, p.finish, p.threads)
              for precs in res.preempted.values() for p in precs
              if not p.hyper]
    for t in sorted({t for s in spans for t in s[:2]}):
        used = sum(th for s0, s1, th in spans if s0 <= t < s1)
        assert used <= machine.spec.cores


def _assert_service_accounting(machine, res, jobs):
    eff = machine.spec.hyper_thread_efficiency
    waste = machine.spec.restart_waste
    for job in jobs:
        granted = sum(r.threads * r.duration * (eff if r.hyper else 1.0)
                      for r in res.records[job.jid])
        wasted = sum(
            p.threads * (p.finish - p.start) * (eff if p.hyper else 1.0)
            * waste for p in res.preempted[job.jid])
        assert job.service == pytest.approx(granted + wasted, rel=1e-9)


# ---------------------------------------------------------------------------
# multi-victim revoke
# ---------------------------------------------------------------------------

def _run_multivictim(machine, policy):
    sink = RecordingSink()
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(max_active=6, sink=sink,
                                         preemption=policy))
    runners = [pool.submit(_narrow_runner(), name=f"r{i}") for i in range(4)]
    # cp ~0.56s, budget 0.1s: overdue the instant it arrives, while the
    # four 17-thread runners hold all 68 cores
    wide = pool.submit(_wide_chain(), name="wide", submit_time=0.05,
                       deadline=0.05 + 0.1)
    res = pool.run()
    ev = [e for e in sink.events if e.family == "preemption"]
    return res, wide, runners, ev


@pytest.fixture(scope="module")
def multivictim_runs(machine):
    single = _run_multivictim(machine, PreemptionPolicy(enabled=True))
    multi = _run_multivictim(
        machine, PreemptionPolicy(enabled=True, max_victims=4))
    return single, multi


class TestMultiVictim:
    def test_seats_preferred_width_and_cuts_latency(self, multivictim_runs):
        (res_s, wide_s, _, ev_s), (res_m, wide_m, _, ev_m) = multivictim_runs
        # single-victim can only free 17 cores at a time: the wide op gets
        # squeezed; the victim set seats the full preferred width
        assert any(e.kind == "squeeze" for e in ev_s)
        mrs = [e for e in ev_m if e.kind == "multi_revoke"]
        assert mrs, "victim-set path never fired"
        assert all(e.data["prefer_threads"] > 17 for e in mrs)
        sets = [e for e in ev_m if e.kind == "revoke"
                and e.data["set_size"] >= 2]
        assert len(sets) >= 2, "a victim SET (>= 2 revokes) was expected"
        assert wide_m.latency < wide_s.latency

    def test_priced_gain_strictly_exceeds_summed_waste(self, machine,
                                                       multivictim_runs):
        _, (res_m, _, _, ev_m) = multivictim_runs
        waste_rate = machine.spec.restart_waste
        for mr in [e for e in ev_m if e.kind == "multi_revoke"]:
            assert mr.data["gain"] > mr.data["waste"]
            # the traced waste is exactly the summed re-billed restart
            # cost of the set revoked at the same instant
            summed = sum(
                e.data["victim_threads"] * e.data["victim_elapsed"]
                * waste_rate
                for e in ev_m if e.kind == "revoke" and e.ts == mr.ts)
            assert mr.data["waste"] == pytest.approx(summed, rel=1e-9)

    def test_single_victim_policy_never_revokes_sets(self, multivictim_runs):
        (res_s, _, _, ev_s), _ = multivictim_runs
        assert all(e.data["set_size"] == 1
                   for e in ev_s if e.kind == "revoke")
        assert not [e for e in ev_s if e.kind == "multi_revoke"]

    def test_pool_invariants_survive_victim_sets(self, machine,
                                                 multivictim_runs):
        _, (res_m, wide_m, runners_m, _) = multivictim_runs
        jobs = runners_m + [wide_m]
        _assert_exactly_once(res_m, jobs)
        _assert_no_oversubscription(machine, res_m)
        _assert_service_accounting(machine, res_m, jobs)


# ---------------------------------------------------------------------------
# admission-level eviction
# ---------------------------------------------------------------------------

def _run_eviction(machine, policy):
    sink = RecordingSink()
    pool = RuntimePool(
        machine=machine,
        config=PoolConfig(max_active=2, sink=sink, preemption=policy,
                          # S4 off: the bystander must stay at ZERO
                          # launches (the hyper lane would seat its ops)
                          runtime=RuntimeConfig(enable_s4=False)))
    blocker = pool.submit(_blocker(), name="blocker")
    bystander = pool.submit(_narrow_runner(n=1), name="bystander",
                            submit_time=0.001)
    urgent = pool.submit(_wide_chain(n=1), name="urgent", submit_time=0.01,
                         deadline=0.02)     # overdue on arrival, queued
    res = pool.run()
    ev = [e for e in sink.events if e.family == "preemption"]
    return res, blocker, bystander, urgent, ev


@pytest.fixture(scope="module")
def eviction_runs(machine):
    off = _run_eviction(machine, PreemptionPolicy(enabled=True))
    on = _run_eviction(
        machine, PreemptionPolicy(enabled=True, evict_admitted=True))
    return off, on


class TestEviction:
    def test_unblocks_overdue_queued_waiter(self, eviction_runs):
        (res_off, *_, u_off, ev_off), (res_on, _, b_on, u_on, ev_on) = \
            eviction_runs
        assert res_off.n_evictions == 0
        assert not [e for e in ev_off if e.kind == "evict"]
        assert res_on.n_evictions == 1
        assert b_on.evictions == 1
        evs = [e for e in ev_on if e.kind == "evict"]
        assert len(evs) == 1
        assert evs[0].key == b_on.jid
        assert evs[0].data["waiter_jid"] == u_on.jid
        assert evs[0].data["waiter_slack"] <= 0.0
        # without the free move the urgent tenant waits out a whole
        # admitted generation; with it, admission happens at its expiry
        assert u_on.latency < u_off.latency / 5

    def test_eviction_is_free(self, machine, eviction_runs):
        _, (res_on, blocker, bystander, urgent, _) = eviction_runs
        # zero restart waste for the evicted tenant: nothing had launched,
        # so nothing was discarded or re-billed
        assert res_on.preempted[bystander.jid] == []
        assert bystander.preemptions == 0
        granted = sum(
            r.threads * r.duration
            * (machine.spec.hyper_thread_efficiency if r.hyper else 1.0)
            for r in res_on.records[bystander.jid])
        assert bystander.service == pytest.approx(granted, rel=1e-9)
        assert res_on.metrics["pool.evictions"] == 1.0

    def test_evicted_job_still_completes(self, machine, eviction_runs):
        _, (res_on, blocker, bystander, urgent, _) = eviction_runs
        jobs = [blocker, bystander, urgent]
        _assert_exactly_once(res_on, jobs)
        _assert_no_oversubscription(machine, res_on)
        _assert_service_accounting(machine, res_on, jobs)


def test_readmit_preserves_original_submit_order():
    g = GraphBuilder("g")
    g.add("X", (4, 4), flops=1e6, bytes_moved=1e4)
    graph = g.build()
    q = JobQueue(max_active=4)
    a = Job(jid=0, name="a", graph=graph)
    b = Job(jid=1, name="b", graph=graph)
    q.submit(a)
    q.submit(b)
    assert q.pop_admissible([], 0.0) is a
    q.readmit(a)
    assert len(q.submitted) == 2      # same submission, not re-counted
    # identical priority/deadline/submit_time: only the queue-seq ticket
    # distinguishes them, and a keeps its original one
    assert q.pop_admissible([], 0.0) is a
    assert q.pop_admissible([], 0.0) is b


# ---------------------------------------------------------------------------
# width migration
# ---------------------------------------------------------------------------

def _run_migration(machine, policy):
    sink = RecordingSink()
    pool = RuntimePool(machine=machine,
                       config=PoolConfig(max_active=6, sink=sink,
                                         preemption=policy))
    # two 17-thread runners (~0.67s) hold 34 cores; the giant arrives
    # overdue and is squeezed into the other 34 by the deadline claim;
    # when the runners drain, only migration can re-seat it at 68
    runners = [pool.submit(_narrow_runner(n=1, flops=2e11), name=f"r{i}")
               for i in range(2)]
    urgent = pool.submit(_giant_op(), name="urgent", submit_time=0.05,
                         deadline=0.05 + 0.1)
    res = pool.run()
    ev = [e for e in sink.events if e.family == "preemption"]
    return res, urgent, runners, ev


@pytest.fixture(scope="module")
def migration_runs(machine):
    off = _run_migration(machine, PreemptionPolicy(enabled=True))
    on = _run_migration(
        machine, PreemptionPolicy(enabled=True, migration=True))
    return off, on


class TestMigration:
    def test_reseats_squeezed_op_wider(self, migration_runs):
        (res_off, u_off, _, ev_off), (res_on, u_on, _, ev_on) = \
            migration_runs
        assert res_off.n_migrations == 0
        assert not [e for e in ev_off if e.kind == "migrate"]
        migs = [e for e in ev_on if e.kind == "migrate"]
        assert migs and res_on.n_migrations == len(migs)
        assert u_on.migrations >= 1
        # the squeezed 34-thread launch is re-seated at a wider width
        assert all(e.data["to_threads"] > e.data["from_threads"]
                   for e in migs)
        assert u_on.latency < u_off.latency

    def test_every_migration_is_priced(self, migration_runs):
        _, (_, _, _, ev_on) = migration_runs
        for e in [e for e in ev_on if e.kind == "migrate"]:
            assert e.data["gain"] > e.data["cost"]
            # the gain is remaining-time improvement, the cost the
            # re-billed partial run — both strictly positive here
            assert e.data["remaining"] > 0.0
            assert e.data["elapsed"] > 0.0

    def test_pool_invariants_survive_migration(self, machine,
                                               migration_runs):
        _, (res_on, urgent, runners, _) = migration_runs
        jobs = runners + [urgent]
        _assert_exactly_once(res_on, jobs)
        _assert_no_oversubscription(machine, res_on)
        _assert_service_accounting(machine, res_on, jobs)


# ---------------------------------------------------------------------------
# armed-but-untriggered economics must be inert (the behavior lock)
# ---------------------------------------------------------------------------

def test_armed_economics_without_deadlines_is_bitwise_inert(machine):
    """No deadline anywhere means no overdue waiter, so multi-victim and
    eviction can never trigger: a pool with those knobs armed must be
    bit-for-bit the single-victim (PR-6) pool on the same mix.  Migration
    is deliberately NOT armed here — it prices moves without deadlines by
    design, so its lock is the off-default (covered by check_parity's
    pool-preempt leg)."""
    def run(policy):
        pool = RuntimePool(machine=machine,
                           config=PoolConfig(max_active=4,
                                             preemption=policy))
        jobs = [pool.submit(_narrow_runner(), name=f"r{i}")
                for i in range(3)]
        jobs.append(pool.submit(_wide_chain(), name="wide",
                                submit_time=0.01))
        return pool.run(), jobs

    base, jobs_b = run(PreemptionPolicy(enabled=True))
    armed, jobs_a = run(PreemptionPolicy(enabled=True, max_victims=4,
                                         evict_admitted=True))
    assert base.makespan == armed.makespan
    assert armed.n_evictions == 0 and armed.n_migrations == 0
    for jb, ja in zip(jobs_b, jobs_a):
        divs = compare_timelines(
            timeline_rows(base.per_job_schedule(jb.jid)),
            timeline_rows(armed.per_job_schedule(ja.jid)),
            label_a="single-victim", label_b="economics-armed")
        assert not divs, divs[:5]


# ---------------------------------------------------------------------------
# stub-adapter unit regressions (satellite fixes)
# ---------------------------------------------------------------------------

class _StubAdapter(StrategyAdapter):
    """Table-driven adapter: hand-built running set and ready frontier,
    dict-backed plans/predictions — pins core rules (tie-breaks, clamp
    re-prediction, placement retries) that pool mixes cannot reach
    deterministically."""

    def __init__(self, clock=1.0):
        self._clock = clock
        self._running: dict = {}
        self.ops: dict = {}
        self.plans: dict = {}
        self.cands: dict = {}
        self.preds: dict = {}          # (key, threads) -> predicted time
        self.slacks: dict = {}
        self.ready: list = []
        self.launched: list[ScheduledOp] = []
        self.revoked: list = []

    @property
    def clock(self):
        return self._clock

    @property
    def running(self):
        return self._running

    def ready_groups(self):
        return [list(self.ready)] if self.ready else []

    def op(self, key):
        return self.ops[key]

    def instance_plan(self, key):
        return self.plans[key]

    def candidates_for(self, key, k):
        return self.cands.get(key, [self.plans[key]])[:k]

    def clamp(self, key, proposal):
        return proposal

    def predict(self, key, threads, variant):
        return self.preds.get((key, threads),
                              self.plans[key].predicted_time)

    def commit(self, key, sched):
        if key in self.ready:
            self.ready.remove(key)
        self._running[key] = sched
        self.launched.append(sched)

    def deadline_slack(self, key):
        return self.slacks.get(key)

    def revoke(self, key):
        sched = self._running.pop(key)
        self.ready.append(key)
        self.revoked.append(key)
        return sched


def _mk_op(uid, cls):
    return Op(uid=uid, name=f"{cls}{uid}", op_class=cls,
              input_shape=(8, 8, 8, 8), flops=1e9, bytes_moved=1e6,
              working_set=1e6, parallel_fraction=0.9)


def _mk_running(uid, cls, threads, start, finish, cores=()):
    return ScheduledOp(op=_mk_op(uid, cls), threads=threads, variant=False,
                       hyper=False, start=start, finish=finish,
                       predicted=finish - start, cores=cores)


def test_hyper_clamp_repredicts_at_clamped_width():
    """Satellite: a hyper-lane launch clamped to the machine width must
    carry the CLAMPED width's prediction, not the unclamped plan's."""
    machine = SimMachine()
    core = StrategyCore(machine, StrategyConfig(), total_cores=8)
    ad = _StubAdapter(clock=1.0)
    ad.ops["w"] = _mk_op(0, "X")
    ad.plans["w"] = OpPlan(16, False, 0.123)      # wider than the machine
    ad.preds[("w", 8)] = 0.456
    ad.preds[("w", 1)] = 1.0                      # serial_time ordering
    ad.ready = ["w"]
    ad._running["r"] = _mk_running(1, "Y", 8, 0.0, 5.0)   # free == 0
    assert core.try_hyper(ad)
    sched = ad.launched[0]
    assert sched.hyper and sched.threads == 8
    assert sched.predicted == 0.456               # re-predicted, not 0.123


def test_victim_tiebreak_prefers_fewest_threads():
    """Satellite: equal remaining time must break on the cheapest revoke
    (fewest threads), not on the opaque node key."""
    machine = SimMachine()
    core = StrategyCore(
        machine,
        StrategyConfig(preemption=PreemptionPolicy(enabled=True)))
    ad = _StubAdapter(clock=1.0)
    ad.ops["w"] = _mk_op(0, "U")
    ad.plans["w"] = OpPlan(32, False, 0.5)
    ad.cands["w"] = [OpPlan(32, False, 0.5)]
    ad.preds[("w", 28)] = 0.6
    ad.slacks["w"] = -1.0
    ad.ready = ["w"]
    ad._running["v_wide"] = _mk_running(1, "A", 40, 0.0, 11.0)
    ad._running["v_narrow"] = _mk_running(2, "B", 28, 0.2, 11.0)
    assert core.try_preempt(ad)
    assert ad.revoked == ["v_narrow"]


def test_victim_tiebreak_equal_threads_prefers_earliest_launched():
    machine = SimMachine()
    core = StrategyCore(
        machine,
        StrategyConfig(preemption=PreemptionPolicy(enabled=True)))
    ad = _StubAdapter(clock=1.0)
    ad.ops["w"] = _mk_op(0, "U")
    ad.plans["w"] = OpPlan(32, False, 0.5)
    ad.cands["w"] = [OpPlan(32, False, 0.5)]
    ad.preds[("w", 34)] = 0.6
    ad.slacks["w"] = -1.0
    ad.ready = ["w"]
    ad._running["v_first"] = _mk_running(1, "A", 34, 0.0, 11.0)
    ad._running["v_second"] = _mk_running(2, "B", 34, 0.2, 11.0)
    assert core.try_preempt(ad)
    assert ad.revoked == ["v_first"]


def test_run_biggest_tries_next_biggest_on_placement_failure():
    """Satellite: under quadrant topology a placement failure of the
    biggest ready op must fall through to the next-biggest op in the SAME
    group, not skip the whole group and idle the cores."""
    machine = SimMachine()
    core = StrategyCore(machine, StrategyConfig(topology="quadrant"))
    spec = machine.spec
    # cross-blacklist (A, C): A must avoid C's quadrant, where the only
    # free cores are — so A's placement fails, and D must launch instead
    core.recorder.record("A", "C", 1.0, 10.0, relation=REL_CROSS)
    core.begin_run()
    q012 = tuple(c for q in (0, 1, 2) for c in spec.quadrant_cores(q))
    q3 = tuple(spec.quadrant_cores(3))
    ad = _StubAdapter(clock=1.0)
    ad._running["rB"] = _mk_running(1, "B", len(q012), 0.0, 101.0,
                                    cores=q012)
    ad._running["rC"] = _mk_running(2, "C", 8, 0.0, 101.0, cores=q3[:8])
    ad.ops["a"] = _mk_op(3, "A")
    ad.ops["d"] = _mk_op(4, "D")
    ad.plans["a"] = OpPlan(18, False, 5.0)        # the biggest
    ad.plans["d"] = OpPlan(8, False, 1.0)         # the next-biggest
    ad.preds[("a", 8)] = 4.0                      # clamped re-prediction
    ad.ready = ["a", "d"]
    assert core.run_biggest(ad)
    assert [s.op.op_class for s in ad.launched] == ["D"]
    assert set(ad.launched[0].cores) <= set(q3)


def test_mean_latency_nan_when_nothing_finished():
    """Satellite: a run where no job finished must not report the same
    0.0 as a perfect run — NaN poisons any aggregate built from it."""
    g = GraphBuilder("g")
    g.add("X", (4, 4), flops=1e6, bytes_moved=1e4)
    job = Job(jid=0, name="j", graph=g.build(), submit_time=0.25)
    res = PoolResult(makespan=0.0, jobs=[job], records={0: []}, events=[],
                     cache_stats={})
    assert math.isnan(res.mean_latency)
    job.finish_time = 1.0
    assert res.mean_latency == pytest.approx(0.75)
