"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kh,d,bq,bk", [
        (2, 128, 4, 2, 64, 64, 64),      # GQA
        (1, 256, 4, 4, 32, 128, 64),     # MHA, rectangular blocks
        (1, 64, 8, 1, 64, 32, 32),       # MQA
        (2, 128, 2, 2, 128, 128, 128),   # single block pair
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, b, s, h, kh, d, bq, bk, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), dtype)
        k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
        v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [32, 64])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 64, 2, 32))
        k = jax.random.normal(ks[1], (1, 64, 2, 32))
        v = jax.random.normal(ks[2], (1, 64, 2, 32))
        out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


class TestWkv6:
    @pytest.mark.parametrize("b,h,s,d,chunk", [
        (2, 2, 128, 64, 64),
        (1, 4, 256, 32, 64),
        (2, 1, 64, 64, 32),
        (1, 2, 128, 64, 128),
    ])
    def test_matches_exact_scan(self, b, h, s, d, chunk):
        ks = jax.random.split(KEY, 5)
        r = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        w = jax.random.uniform(ks[3], (b, h, s, d), minval=0.5, maxval=0.999)
        u = jax.random.normal(ks[4], (h, d)) * 0.5
        out, st = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
        oref, sref = wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                                   atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(sref),
                                   atol=3e-4, rtol=1e-3)

    def test_strong_decay_stable(self):
        """Exponents clip instead of overflowing under harsh decay."""
        ks = jax.random.split(KEY, 5)
        b, h, s, d = 1, 1, 128, 32
        r = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        w = jax.random.uniform(ks[3], (b, h, s, d), minval=1e-4, maxval=0.2)
        u = jnp.zeros((h, d))
        out, st = wkv6(r, k, v, w, u, chunk=64, interpret=True)
        assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(st).all())
        oref, _ = wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                                   atol=5e-4, rtol=5e-3)

    def test_model_chunked_path_matches(self):
        """The jnp chunked path used by the model equals the oracle too."""
        from repro.models.layers import rwkv6_linear_attention
        ks = jax.random.split(KEY, 5)
        b, h, s, d = 1, 2, 128, 32
        r = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        w = jax.random.uniform(ks[3], (b, h, s, d), minval=0.6, maxval=0.999)
        u = jax.random.normal(ks[4], (h, d)) * 0.5
        out, st = rwkv6_linear_attention(r, k, v, w, u, chunk=32)
        oref, sref = wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                                   atol=3e-4, rtol=1e-3)


class TestRglru:
    @pytest.mark.parametrize("b,s,r,chunk", [
        (2, 128, 64, 64),
        (1, 256, 128, 128),
        (3, 64, 32, 16),
    ])
    def test_matches_exact_scan(self, b, s, r, chunk):
        ks = jax.random.split(KEY, 2)
        a = jax.random.uniform(ks[0], (b, s, r), minval=0.001, maxval=0.9995)
        x = jax.random.normal(ks[1], (b, s, r))
        h, hl = rglru(a, x, chunk=chunk, interpret=True)
        href, hlref = rglru_ref(a, x)
        np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(hl), np.asarray(hlref),
                                   atol=1e-5, rtol=1e-5)

    def test_extreme_decay(self):
        """No log-space overflow: exact sequential inner loop."""
        b, s, r = 1, 64, 32
        a = jnp.full((b, s, r), 1e-6)
        x = jnp.ones((b, s, r))
        h, _ = rglru(a, x, chunk=32, interpret=True)
        href, _ = rglru_ref(a, x)
        np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                                   atol=1e-6)

    def test_model_scan_matches_kernel_ref(self):
        from repro.models.layers import rglru_scan
        ks = jax.random.split(KEY, 2)
        a = jax.random.uniform(ks[0], (2, 64, 16), minval=0.1, maxval=0.99)
        x = jax.random.normal(ks[1], (2, 64, 16))
        h_model, hl_model = rglru_scan(a, x)
        # note: model scan multiplies x by sqrt(1-a^2) internally, matching
        href, hlref = rglru_ref(a, x)
        np.testing.assert_allclose(np.asarray(h_model), np.asarray(href),
                                   atol=1e-5, rtol=1e-4)
