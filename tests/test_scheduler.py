"""Co-run scheduler (paper Strategies 3-4) + baselines."""

import pytest

from repro.core import (ConcurrencyRuntime, RuntimeConfig, SimMachine,
                        build_paper_graph, manual_best_schedule,
                        uniform_schedule)


@pytest.fixture(scope="module")
def machine():
    return SimMachine()


@pytest.fixture(scope="module")
def graph():
    return build_paper_graph("resnet50")


def _run(graph, **cfg):
    rt = ConcurrencyRuntime(config=RuntimeConfig(**cfg))
    rt.profile(graph)
    return rt.execute_step(graph)


class TestCorunScheduler:
    def test_all_ops_execute_exactly_once(self, graph):
        res = _run(graph)
        assert len(res.records) == graph.n_ops
        assert len({r.op.uid for r in res.records}) == graph.n_ops

    def test_dependencies_respected(self, graph):
        res = _run(graph)
        start = {r.op.uid: r.start for r in res.records}
        finish = {r.op.uid: r.finish for r in res.records}
        for op in graph.ops.values():
            for d in op.deps:
                assert finish[d] <= start[op.uid] + 1e-12

    def test_core_capacity_never_exceeded(self, graph, machine):
        res = _run(graph)
        events = sorted({r.start for r in res.records}
                        | {r.finish for r in res.records})
        for t in events:
            used = sum(r.threads for r in res.records
                       if not r.hyper and r.start <= t < r.finish)
            assert used <= machine.spec.cores

    def test_s3_beats_serial(self, graph):
        serial = _run(graph, enable_s3=False, enable_s4=False)
        corun = _run(graph, enable_s3=True, enable_s4=False)
        assert corun.makespan < serial.makespan
        assert corun.mean_corunning > serial.mean_corunning

    def test_deterministic(self, graph):
        a = _run(graph)
        b = _run(graph)
        assert a.makespan == b.makespan
        assert [r.op.uid for r in a.records] == [r.op.uid for r in b.records]

    def test_events_timeline_nonempty(self, graph):
        res = _run(graph)
        assert len(res.events) >= 2 * graph.n_ops  # launch + finish each


class TestBaselines:
    def test_oversubscription_penalty(self, graph, machine):
        """Paper Table I: inter*intra beyond physical cores hurts."""
        good = uniform_schedule(graph, machine, intra=34, inter=2)
        oversub = uniform_schedule(graph, machine, intra=136, inter=2)
        assert oversub.makespan > good.makespan

    def test_inter_op_helps(self, graph, machine):
        """Paper Table I: (2,34) beats (1,68) on these networks."""
        rec = uniform_schedule(graph, machine, intra=68, inter=1)
        two = uniform_schedule(graph, machine, intra=34, inter=2)
        assert two.makespan < rec.makespan

    def test_manual_grid(self, graph, machine):
        best, cfg = manual_best_schedule(graph, machine)
        assert cfg[0] in (1, 2, 4) and cfg[1] in (17, 34, 68)


class TestEndToEnd:
    @pytest.mark.parametrize("model,band", [
        ("resnet50", (1.2, 2.0)),
        ("dcgan", (1.2, 2.0)),
        ("inception_v3", (1.0, 1.6)),
    ])
    def test_speedup_vs_recommendation(self, machine, model, band):
        """Paper Fig 3.d: 17%-49% improvement over the TF recommendation
        (bands widened for the simulated machine; see EXPERIMENTS.md)."""
        g = build_paper_graph(model)
        rt = ConcurrencyRuntime()
        s = rt.train(g, total_steps=1000)
        assert band[0] <= s.speedup <= band[1]

    def test_close_to_manual(self, machine):
        """Paper: runtime is within a few % of (or better than) exhaustive
        manual tuning."""
        g = build_paper_graph("dcgan")
        rt = ConcurrencyRuntime()
        rt.profile(g)
        ours = rt.execute_step(g).makespan
        manual, _ = manual_best_schedule(g, machine)
        assert ours <= manual.makespan * 1.15

    def test_profiling_overhead_small(self, machine):
        """Paper §IV-A: profiling steps are <0.05% of total training."""
        g = build_paper_graph("resnet50")
        rt = ConcurrencyRuntime()
        s = rt.train(g, total_steps=10000)
        assert s.profiling_overhead < 0.05
