"""Cluster pool benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Claims measured (the Issue-10 acceptance floors are the asserts):

* ``cluster_vs_round_robin`` — demand-aware routing STRICTLY beats
  round-robin aggregate throughput on the 8-job resnet50/dcgan mix
  (round-robin alternates by arrival index, which lands every resnet50
  on machine 0 and every dcgan on machine 1 — maximal demand imbalance;
  the demand router prices each job's core-seconds against live load
  and interleaves them).
* ``cluster_vs_single_machine`` — two machines under demand routing
  deliver >= 1.6x the aggregate throughput of one machine on the same
  mix (perfect scaling is 2.0x; profiling is shared through the
  fingerprint-keyed PlanCache, so what is lost is only imbalance).
* ``cluster_fairness`` — slowdown Jain index (cluster latency over
  solo-run makespan, per job) stays >= 0.85: routing for throughput
  may not starve anyone.
* ``cluster_rebalance_latency`` — a deadline-critical waiter behind a
  hog is withdrawn to an idle machine; its latency strictly beats the
  stay-put (rebalance disabled) run, at zero restart waste.
* ``cluster_trace_export`` — a traced 2-machine run fires FAM_CLUSTER
  route events, they survive the metrics registry, and the Perfetto
  export carries per-machine process lanes (positive coverage for the
  family the single-machine trace artifact legitimately excludes).
"""

from __future__ import annotations

from repro.cluster import ClusterPool, RouterConfig
from repro.core import SimMachine, build_paper_graph
from repro.hw import ClusterSpec
from repro.multitenant import PoolConfig, RuntimePool
from repro.multitenant.job import jain

# the Issue-10 mix: 8 jobs alternating resnet50/dcgan, simultaneous
# arrivals — adversarial for arrival-index routing, easy for demand
MIX = [("resnet50" if i % 2 == 0 else "dcgan") for i in range(8)]

_RESULTS: dict | None = None


def _mix_pool(n_machines: int, policy: str, **router_kw):
    pool = ClusterPool(ClusterSpec.homogeneous(n_machines),
                       config=PoolConfig(max_active=3),
                       router=RouterConfig(policy=policy, **router_kw))
    for i, model in enumerate(MIX):
        pool.submit(build_paper_graph(model), name=f"{model}.{i}")
    return pool


def _results() -> dict:
    """One shared set of runs — deterministic, and several bench
    functions report different slices."""
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = {
            "demand": _mix_pool(2, "demand").run(),
            "rr": _mix_pool(2, "round_robin").run(),
            "single": _mix_pool(1, "demand").run(),
        }
        # per-model solo makespans (each job alone on one machine) —
        # the slowdown denominator
        solo = {}
        for model in dict.fromkeys(MIX):
            p = RuntimePool(machine=SimMachine(),
                            config=PoolConfig(max_active=3))
            p.submit(build_paper_graph(model))
            solo[model] = p.run().makespan
        _RESULTS["solo"] = solo
    return _RESULTS


def cluster_vs_round_robin() -> list[str]:
    r = _results()
    demand, rr = r["demand"], r["rr"]
    rows = [
        f"cluster/demand_thpt,{demand.makespan*1e6:.1f},"
        f"thpt={demand.aggregate_throughput:.1f}ops/s",
        f"cluster/round_robin_thpt,{rr.makespan*1e6:.1f},"
        f"thpt={rr.aggregate_throughput:.1f}ops/s",
        f"cluster/demand_vs_rr,0,"
        f"ratio={demand.aggregate_throughput/rr.aggregate_throughput:.3f}x",
    ]
    assert demand.aggregate_throughput > rr.aggregate_throughput, \
        "demand-aware routing must strictly beat round-robin throughput"
    return rows


def cluster_vs_single_machine() -> list[str]:
    r = _results()
    demand, single = r["demand"], r["single"]
    ratio = demand.aggregate_throughput / single.aggregate_throughput
    rows = [
        f"cluster/single_machine_thpt,{single.makespan*1e6:.1f},"
        f"thpt={single.aggregate_throughput:.1f}ops/s",
        f"cluster/scaling_2m,0,ratio={ratio:.3f}x",
    ]
    assert ratio >= 1.6, \
        f"2 machines must deliver >=1.6x single-machine throughput " \
        f"(got {ratio:.3f}x)"
    return rows


def cluster_fairness() -> list[str]:
    r = _results()
    demand, solo = r["demand"], r["solo"]
    lats = demand.latencies()
    slowdowns = [lats[cj.cjid] / solo[cj.name.split(".")[0]]
                 for cj in demand.cluster_jobs if cj.cjid in lats]
    j = jain(slowdowns)
    rows = [f"cluster/slowdown_jain,0,jain={j:.3f}",
            f"cluster/worst_slowdown,0,x={max(slowdowns):.3f}"]
    assert j >= 0.85, \
        f"demand routing must keep slowdown-Jain >= 0.85 (got {j:.3f})"
    return rows


def cluster_rebalance_latency() -> list[str]:
    """Deadline-critical waiter behind a hog: moved vs stay-put."""
    def run(rebalance: bool):
        pool = ClusterPool(
            ClusterSpec.homogeneous(2),
            config=PoolConfig(max_active=1),
            router=RouterConfig(rebalance=rebalance))
        pool.submit(build_paper_graph("resnet50"), name="hog", machine=0)
        pool.submit(build_paper_graph("dcgan"), name="urgent", machine=0,
                    submit_time=0.001, deadline=0.04)
        res = pool.run()
        urgent = next(cj for cj in res.cluster_jobs if cj.name == "urgent")
        return res, urgent

    moved_res, moved = run(True)
    stay_res, stayed = run(False)
    rows = [
        f"cluster/rebalanced_latency,{moved.latency*1e6:.1f},"
        f"moves={moved.moves}",
        f"cluster/stayput_latency,{stayed.latency*1e6:.1f},moves=0",
        f"cluster/rebalance_gain,0,"
        f"x={stayed.latency/moved.latency:.3f}",
    ]
    assert moved_res.n_rebalances == 1 and moved.moves == 1, \
        "the deadline-critical waiter must be rebalanced exactly once"
    assert moved.latency < stayed.latency, \
        "rebalancing to an idle machine must beat waiting out the hog"
    return rows


def cluster_trace_export(path: str | None = None) -> list[str]:
    """Positive FAM_CLUSTER coverage: route events fire, metrics count
    them, Perfetto export carries per-machine lanes + flow arrows.
    Default path: a temp dir (the bench checks structure, the artifact
    of record is the CLI's ``--trace-out``)."""
    import os
    import tempfile

    from repro.core import StrategyConfig
    from repro.obs import FAM_CLUSTER, RecordingSink
    from repro.obs.metrics import metrics_from_events
    from repro.obs.perfetto import MACHINE_PID_BASE, export_cluster_trace

    if path is None:
        path = os.path.join(tempfile.mkdtemp(prefix="cluster_trace_"),
                            "cluster_trace.json")
    sink = RecordingSink()
    pool = ClusterPool(
        ClusterSpec.homogeneous(2),
        config=PoolConfig(max_active=3,
                          strategy=StrategyConfig(sink=sink)))
    for i, model in enumerate(MIX[:4]):
        pool.submit(build_paper_graph(model), name=f"{model}.{i}")
    res = pool.run()
    routes = [e for e in sink.events if e.family == FAM_CLUSTER]
    reg = metrics_from_events(sink.events)
    snap = reg.snapshot()
    routed = sum(snap.get(f"cluster.machine.{m}.routed", 0)
                 for m in range(2))
    trace = export_cluster_trace(res, path, sink.events)
    pids = {e.get("pid") for e in trace["traceEvents"]}
    assert routes, "a 2-machine traced run must emit cluster events"
    assert routed == 4, \
        f"metrics must count one routed job per submission (got {routed})"
    assert {MACHINE_PID_BASE, MACHINE_PID_BASE + 1} <= pids, \
        "Perfetto export must carry one process lane per machine"
    return [
        f"cluster/trace_events,{len(routes)},families=cluster",
        f"cluster/trace_perfetto_events,{len(trace['traceEvents'])},"
        f"machine_lanes=2",
    ]


ALL = [cluster_vs_round_robin, cluster_vs_single_machine,
       cluster_fairness, cluster_rebalance_latency, cluster_trace_export]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
