"""Misprediction benchmark: closed-loop EWMA feedback vs frozen plans.

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Scenario: the 4-job bench mix, but PROFILING ran on a perturbed timing
context — every op's measured time is off by a deterministic per-op
factor in [0.5, 2.0] (log-uniform in the op's class+shape hash), the
"stale curves / drifted machine" case.  A constant per-op factor leaves
each curve's optimal width intact (the argmin is scale-invariant), so
Strategy 1/2 widths stay right and what breaks is exactly what the
closed loop re-estimates: cross-op predicted-time ORDER — Strategy 3's
candidate ranking, admission horizon guard, and run-biggest fallback all
compare predictions ACROSS ops, so per-op scale errors mis-schedule even
with perfect widths.

Claims measured:

* ``feedback_off_mispredicted`` / ``feedback_ewma_mispredicted`` —
  aggregate mix throughput under frozen vs adaptive plan stores, same
  perturbed profiles, same execution machine.  Asserted:
  ``feedback="ewma"`` >= ``feedback="off"`` (the closed loop must not
  lose to the open loop it corrects).
* ``feedback_prediction_error`` — mean |log(observed/predicted)| of the
  first vs last quartile of completions under ``ewma``: the corrections
  must actually converge toward observed service, not merely reshuffle.
* ``feedback_exact_profiles`` — the control: with UNperturbed profiles
  the adaptive store's throughput stays within 2% of frozen (feedback
  may not tax the well-predicted case).
"""

from __future__ import annotations

import math
import zlib

from repro.core import SimMachine, build_paper_graph
from repro.core.simmachine import Placement
from repro.multitenant import PoolConfig, RuntimePool

MIX = [("resnet50", 1.0), ("dcgan", 1.0), ("resnet50", 2.0), ("dcgan", 1.0)]


class MispredictedMachine(SimMachine):
    """A profiling context whose measurements are off by a deterministic
    per-op factor in [0.5, 2.0] — what a stale or drifted profile looks
    like.  Used ONLY as ``RuntimePool(profile_machine=...)``; execution
    still runs on the true machine."""

    def __init__(self, *args, perturb_seed: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self.perturb_seed = perturb_seed

    def _factor(self, op) -> float:
        key = f"perturb:{self.perturb_seed}:{op.op_class}:{op.input_shape}"
        h = zlib.crc32(key.encode()) / 0xFFFFFFFF
        return 0.5 * (4.0 ** h)          # log-uniform in [0.5, 2.0]

    def op_time(self, op, placement: Placement, *,
                bw_share: float = 1.0) -> float:
        return super().op_time(op, placement,
                               bw_share=bw_share) * self._factor(op)

    @property
    def fingerprint(self):
        # a perturbed context is NOT the true machine: tag the fingerprint
        # so a PlanCache bound to one refuses curves from the other
        return (*super().fingerprint, "perturbed", self.perturb_seed)


def _run_mix(feedback: str, *, perturbed: bool):
    machine = SimMachine()
    pool = RuntimePool(
        machine=machine,
        profile_machine=(MispredictedMachine() if perturbed else None),
        config=PoolConfig(max_active=3,
                          feedback=(feedback if feedback != "off"
                                    else None)))
    for i, (model, prio) in enumerate(MIX):
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}")
    return pool, pool.run()


def _log_error(res, records) -> float:
    errs = [abs(math.log(r.duration / max(r.predicted, 1e-12)))
            for r in records if not r.hyper]
    return sum(errs) / max(len(errs), 1)


def feedback_on_mispredicted_mix() -> list[str]:
    _, off = _run_mix("off", perturbed=True)
    pool, ew = _run_mix("ewma", perturbed=True)
    rows = [
        f"fb/feedback_off_mispredicted,{off.makespan*1e6:.1f},"
        f"thpt={off.aggregate_throughput:.1f}ops/s",
        f"fb/feedback_ewma_mispredicted,{ew.makespan*1e6:.1f},"
        f"thpt={ew.aggregate_throughput:.1f}ops/s",
        f"fb/feedback_speedup,{ew.makespan*1e6:.1f},"
        f"speedup={off.makespan/ew.makespan:.3f}x",
        f"fb/feedback_corrections,"
        f"{ew.feedback_stats['observed']:.0f},"
        f"points={ew.feedback_stats['points']:.0f}",
    ]
    assert ew.aggregate_throughput >= off.aggregate_throughput, (
        "feedback='ewma' must not lose to frozen plans on the "
        f"mispredicted mix (ewma {ew.aggregate_throughput:.2f} vs "
        f"off {off.aggregate_throughput:.2f} ops/s)")
    # convergence: launches late in the run are predicted better than the
    # first launches (corrections absorb the per-op perturbation)
    recs = sorted((r for rs in ew.records.values() for r in rs),
                  key=lambda r: r.start)
    q = max(len(recs) // 4, 1)
    early, late = _log_error(ew, recs[:q]), _log_error(ew, recs[-q:])
    rows.append(f"fb/feedback_prediction_error,0,"
                f"early={early:.3f} late={late:.3f}")
    # correction magnitudes through the metrics registry: how far the
    # blended corrections ended up from the (perturbed) frozen curves
    rows.append(
        f"fb/feedback_correction_mag,0,"
        f"mean={ew.metrics['feedback.mean_abs_log_correction']:.3f} "
        f"max={ew.metrics['feedback.max_abs_log_correction']:.3f}")
    assert ew.metrics["feedback.mean_abs_log_correction"] > 0.0, (
        "perturbed profiles must leave nonzero corrections in the "
        "feedback.* gauges")
    assert late < early, (
        f"EWMA corrections must converge: late-run prediction error "
        f"{late:.3f} not below early-run {early:.3f}")
    return rows


def feedback_neutral_on_exact_profiles() -> list[str]:
    """The control: with profiles measured on the TRUE machine, arming
    feedback may not tax throughput (real observations still differ from
    solo predictions by contention/jitter, so bitwise equality is not
    expected — the zero-error parity suite pins that separately)."""
    _, off = _run_mix("off", perturbed=False)
    _, ew = _run_mix("ewma", perturbed=False)
    ratio = ew.aggregate_throughput / off.aggregate_throughput
    rows = [f"fb/feedback_exact_profiles,{ew.makespan*1e6:.1f},"
            f"thpt_ratio={ratio:.3f}"]
    assert ratio >= 0.98, (
        f"feedback must be ~free when profiles are accurate "
        f"(throughput ratio {ratio:.3f} < 0.98)")
    return rows


ALL = [feedback_on_mispredicted_mix, feedback_neutral_on_exact_profiles]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
