"""Roofline table renderer: reads experiments/dryrun.json and emits the
per-(arch x shape) roofline rows (EXPERIMENTS.md §Roofline source)."""

from __future__ import annotations

import json
import os

_EXP = os.path.join(os.path.dirname(__file__), "..", "experiments")
_MERGED = os.path.join(_EXP, "dryrun_merged.json")
DEFAULT_PATH = (_MERGED if os.path.exists(_MERGED)
                else os.path.join(_EXP, "dryrun.json"))


def rows_from_records(records: list[dict]) -> list[str]:
    out = []
    for r in records:
        if r.get("mesh_name") != "single":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        if "skipped" in r:
            out.append(f"{name},0,skipped({r['skipped'][:40]})")
            continue
        if "error" in r:
            out.append(f"{name},0,ERROR")
            continue
        rf = r["roofline"]
        step_us = rf["step_s_overlapped"] * 1e6
        ratio = r.get("useful_flops_ratio")
        frac = (min(rf["compute_s"] / rf["step_s_overlapped"], 1.0)
                if rf["step_s_overlapped"] else 0.0)
        out.append(
            f"{name},{step_us:.0f},"
            f"dom={rf['dominant']};compute_s={rf['compute_s']:.4f};"
            f"memory_s={rf['memory_s']:.4f};"
            f"collective_s={rf['collective_s']:.4f};"
            f"useful_ratio={ratio:.3f};roofline_frac={frac:.3f};"
            f"fits_hbm={r.get('fits_hbm')}" if ratio else
            f"{name},{step_us:.0f},dom={rf['dominant']}")
    return out


def roofline_table(path: str = DEFAULT_PATH) -> list[str]:
    if not os.path.exists(path):
        return ["roofline/NOT_RUN,0,run python -m repro.launch.dryrun first"]
    with open(path) as f:
        records = json.load(f)
    # merged records carry a "key" field with mesh_name in position 2
    for r in records:
        if "mesh_name" not in r and "key" in r:
            r["mesh_name"] = r["key"][2]
    return rows_from_records(records)


ALL = [roofline_table]
