"""Kernel micro-benchmarks: wall time of the jnp oracle paths on CPU (the
Pallas kernels themselves are TPU-target; interpret mode timing is not
meaningful, so oracle timing + kernel-vs-oracle agreement is reported)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def flash_attention_oracle() -> list[str]:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.PRNGKey(0)
    rows = []
    for (b, s, h, kh, d) in [(1, 512, 8, 2, 64), (2, 1024, 8, 8, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, kh, d))
        v = jax.random.normal(ks[2], (b, s, kh, d))
        ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        us = _timeit(ref, q, k, v)
        out = flash_attention(q, k, v, causal=True, block_q=128,
                              block_k=128, interpret=True)
        err = float(jnp.abs(out - ref(q, k, v)).max())
        rows.append(f"kernel/flash/b{b}s{s}h{h}kv{kh},{us:.0f},"
                    f"kernel_err={err:.1e}")
    return rows


def wkv6_oracle() -> list[str]:
    from repro.kernels.rwkv6.ops import wkv6
    from repro.kernels.rwkv6.ref import wkv6_ref
    key = jax.random.PRNGKey(1)
    rows = []
    for (b, h, s, d) in [(1, 4, 512, 64), (2, 8, 256, 64)]:
        ks = jax.random.split(key, 5)
        r = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        w = jax.random.uniform(ks[3], (b, h, s, d), minval=0.6,
                               maxval=0.999)
        u = jax.random.normal(ks[4], (h, d)) * 0.5
        ref = jax.jit(wkv6_ref)
        us = _timeit(ref, r, k, v, w, u)
        out, _ = wkv6(r, k, v, w, u, chunk=64, interpret=True)
        err = float(jnp.abs(out - ref(r, k, v, w, u)[0]).max())
        rows.append(f"kernel/wkv6/b{b}h{h}s{s},{us:.0f},kernel_err={err:.1e}")
    return rows


def rglru_oracle() -> list[str]:
    from repro.kernels.rglru.ops import rglru
    from repro.kernels.rglru.ref import rglru_ref
    key = jax.random.PRNGKey(2)
    rows = []
    for (b, s, r_) in [(2, 1024, 256), (4, 512, 512)]:
        ks = jax.random.split(key, 2)
        a = jax.random.uniform(ks[0], (b, s, r_), minval=0.01, maxval=0.999)
        x = jax.random.normal(ks[1], (b, s, r_))
        ref = jax.jit(rglru_ref)
        us = _timeit(ref, a, x)
        h, _ = rglru(a, x, chunk=128, interpret=True)
        err = float(jnp.abs(h - ref(a, x)[0]).max())
        rows.append(f"kernel/rglru/b{b}s{s}r{r_},{us:.0f},"
                    f"kernel_err={err:.1e}")
    return rows


def train_step_smoke() -> list[str]:
    """Real wall time of a smoke-scale train step per arch family."""
    from repro.configs import get_config
    from repro.models import zoo
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, init_state, make_train_step
    rows = []
    for arch in ("olmo-1b", "mixtral-8x7b", "rwkv6-1.6b",
                 "recurrentgemma-2b", "whisper-small"):
        cfg = get_config(arch, smoke=True)
        tcfg = TrainConfig(microbatches=1,
                           optimizer=AdamWConfig(total_steps=10))
        state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, tcfg))
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
        batch["targets"] = jnp.roll(batch["tokens"], -1, 1)
        if zoo.needs_frontend(cfg):
            batch["frontend"] = jnp.zeros(
                (4, cfg.n_frontend_tokens, cfg.d_model))
        state, m = step(state, batch)          # compile
        us = _timeit(lambda s, b: step(s, b)[1]["loss"], state, batch, n=3)
        rows.append(f"train_smoke/{arch},{us:.0f},"
                    f"loss={float(m['loss']):.3f}")
    return rows


ALL = [flash_attention_oracle, wkv6_oracle, rglru_oracle, train_step_smoke]
