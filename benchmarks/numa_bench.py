"""Topology-aware placement benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Scenario: the PR-1/PR-2 4-job training mix run twice through identically
configured pools — ``topology="flat"`` (the paper's 68-core pool, bit-
for-bit the pre-topology scheduler) and ``topology="quadrant"`` (every
launch books a concrete core set; bandwidth shares computed from actual
quadrant co-residents).

Claims measured:

* ``numa_quadrant_vs_flat`` — quadrant placement's aggregate throughput
  on the 4-job mix is at least flat's (the asserted speedup floor: the
  placement policy spends its locality boost where co-runs used to pay
  all-to-all interleaving waste, and the spill penalty never exceeds the
  win on this mix).
* ``numa_placement_locality`` — how well the policy separates tenants:
  the share of launches that stayed inside a single quadrant, and the
  straddle histogram (quadrants touched per launch).
"""

from __future__ import annotations

from repro.core import SimMachine, build_paper_graph
from repro.multitenant import PoolConfig, RuntimePool

MACHINE = SimMachine()

MIX = [("resnet50", 1.0), ("dcgan", 1.0), ("resnet50", 2.0), ("dcgan", 1.0)]

_RESULTS = None


def _run_pool(topology: str | None):
    pool = RuntimePool(machine=MACHINE,
                       config=PoolConfig(max_active=3, topology=topology))
    for i, (model, prio) in enumerate(MIX):
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}")
    return pool.run()


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = (_run_pool(None), _run_pool("quadrant"))
    return _RESULTS


def numa_quadrant_vs_flat() -> list[str]:
    flat, quad = _results()
    ratio = quad.aggregate_throughput / flat.aggregate_throughput
    rows = [
        f"numa/flat_makespan,{flat.makespan*1e6:.1f},"
        f"thpt={flat.aggregate_throughput:.1f}ops/s",
        f"numa/quadrant_makespan,{quad.makespan*1e6:.1f},"
        f"thpt={quad.aggregate_throughput:.1f}ops/s",
        f"numa/quadrant_vs_flat,{quad.makespan*1e6:.1f},"
        f"speedup={ratio:.3f}x",
    ]
    assert ratio >= 1.0, (
        "quadrant placement must not lose to flat on the 4-job mix "
        f"(ratio {ratio:.3f})")
    return rows


def numa_placement_locality() -> list[str]:
    _, quad = _results()
    spec = MACHINE.spec
    histogram: dict[int, int] = {}
    for recs in quad.records.values():
        for r in recs:
            if r.hyper:
                continue
            n = len({spec.quadrant_of_core(c) for c in r.cores})
            histogram[n] = histogram.get(n, 0) + 1
    # launch/locality counts come from the metrics registry (the
    # placement.* gauges on ``PoolResult.metrics``); the straddle
    # histogram is recomputed from the records and cross-checks them
    placed = int(quad.metrics["placement.launches"])
    local = int(quad.metrics["placement.local"])
    assert placed == sum(histogram.values()), \
        "placement.launches gauge must match the booked records"
    assert local == histogram.get(1, 0), \
        "placement.local gauge must match the single-quadrant records"
    rows = [
        f"numa/quadrant_local_launches,{local},"
        f"of={placed}"
        f"({100.0*quad.metrics['placement.local_fraction']:.0f}%)",
    ]
    for n in sorted(histogram):
        rows.append(f"numa/straddle_{n}q,{histogram[n]},launches")
    # every placed launch books exactly its width in unique cores — the
    # bench doubles as a cheap placement-integrity check in CI
    for recs in quad.records.values():
        for r in recs:
            if not r.hyper:
                assert len(set(r.cores)) == r.threads
    assert local > 0, "placement never packed a launch quadrant-locally"
    return rows


ALL = [numa_quadrant_vs_flat, numa_placement_locality]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
