"""Paper-table reproductions (one function per table/figure).

Every function returns a list of CSV rows ``name,us_per_call,derived`` —
``us_per_call`` is the simulated/measured op or step time in
microseconds, ``derived`` is the paper's headline statistic for that
table (speedup, accuracy, ...).  benchmarks/run.py prints them all.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (ConcurrencyRuntime, HillClimbProfiler, Op,
                        Placement, RegressionSuite, RuntimeConfig,
                        SimMachine, build_paper_graph,
                        manual_best_schedule, paper_case_lists,
                        uniform_schedule, PAPER_INPUT_SIZES)

MACHINE = SimMachine()


def _oracle(machine):
    def fn(op, threads, variant):
        return machine.op_time(op, Placement(threads, cache_sharing=variant))
    return fn


def fig1_scaling_curves() -> list[str]:
    """Fig 1: execution time vs thread count for the three conv ops —
    the concave curves with interior optima that motivate everything."""
    rows = []
    specs = [("Conv2DBackpropFilter", 740.0, 260.0, 0.95),
             ("Conv2DBackpropInput", 700.0, 240.0, 0.95),
             ("Conv2D", 660.0, 200.0, 0.96)]
    shape = (32, 8, 8, 384)
    elems = float(np.prod(shape))
    for cls, fl, by, pf in specs:
        op = Op(uid=0, name=cls, op_class=cls, input_shape=shape,
                flops=elems * fl, bytes_moved=elems * by,
                working_set=elems * by, parallel_fraction=pf)
        t_best, pl = MACHINE.best_time_exhaustive(op)
        for t in (1, 8, 16, 26, 34, 45, 56, 68):
            dt = MACHINE.op_time(op, Placement(t, cache_sharing=(t % 2 == 0)))
            rows.append(f"fig1/{cls}/t{t},{dt*1e6:.1f},"
                        f"best_t={pl.threads}")
    return rows


def table1_concurrency_grid() -> list[str]:
    """Table I: NN step time across (inter, intra) parallelism configs."""
    rows = []
    for model in ("resnet50", "dcgan"):
        g = build_paper_graph(model)
        base = uniform_schedule(g, MACHINE, intra=68, inter=1).makespan
        for inter in (1, 2, 4):
            for intra in (34, 68, 136):
                res = uniform_schedule(g, MACHINE, intra=intra, inter=inter)
                rows.append(
                    f"table1/{model}/inter{inter}_intra{intra},"
                    f"{res.makespan*1e6:.1f},"
                    f"speedup={base/res.makespan:.2f}")
    return rows


def table2_input_size() -> list[str]:
    """Table II: best thread count grows with input size."""
    rows = []
    for shape in PAPER_INPUT_SIZES:
        elems = float(np.prod(shape))
        op = Op(uid=0, name="bf", op_class="Conv2DBackpropFilter",
                input_shape=shape, flops=elems * 740.0,
                bytes_moved=elems * 260.0, working_set=elems * 260.0,
                parallel_fraction=0.95)
        t_best, pl = MACHINE.best_time_exhaustive(op)
        t68 = MACHINE.op_time(op, Placement(68, cache_sharing=True))
        rows.append(
            f"table2/bwd_filter/{'x'.join(map(str, shape))},"
            f"{t_best*1e6:.1f},"
            f"best_threads={pl.threads};variance_vs68={100*(t68/t_best-1):.1f}%")
    return rows


def table3_corun() -> list[str]:
    """Table III: sequential vs hyper-threaded vs split-core co-run of the
    Conv2DBackpropFilter + Conv2DBackpropInput pair."""
    shape = (32, 8, 8, 2048)
    elems = float(np.prod(shape))
    bf = Op(uid=0, name="bf", op_class="Conv2DBackpropFilter",
            input_shape=shape, flops=elems * 740.0,
            bytes_moved=elems * 260.0, working_set=elems * 260.0,
            parallel_fraction=0.95)
    bi = Op(uid=1, name="bi", op_class="Conv2DBackpropInput",
            input_shape=shape, flops=elems * 700.0,
            bytes_moved=elems * 240.0, working_set=elems * 240.0,
            parallel_fraction=0.95)
    seq = (MACHINE.op_time(bf, Placement(68, cache_sharing=True))
           + MACHINE.op_time(bi, Placement(68, cache_sharing=True)))
    ht = max(MACHINE.op_time(bf, Placement(68, cache_sharing=True),
                             bw_share=0.5),
             MACHINE.op_time(bi, Placement(68, cache_sharing=True,
                                           hyper_thread=True),
                             bw_share=0.5))
    split = max(MACHINE.op_time(bf, Placement(34, cache_sharing=True),
                                bw_share=0.5),
                MACHINE.op_time(bi, Placement(34, cache_sharing=True),
                                bw_share=0.5))
    rows = [
        f"table3/sequential_68,{seq*1e6:.1f},speedup=1.00",
        f"table3/corun_hyperthread_68+68,{ht*1e6:.1f},"
        f"speedup={seq/ht:.2f}",
        f"table3/corun_split_34+34,{split*1e6:.1f},"
        f"speedup={seq/split:.2f}",
    ]
    return rows


def table4_regression_accuracy() -> list[str]:
    """Table IV: regression-model accuracy (trained on resnet/dcgan/
    inception ops, tested on alexnet) — low, as the paper found."""
    oracle = _oracle(MACHINE)
    train_ops = []
    for m in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(m)
        seen = set()
        for op in g.ops.values():
            if op.size_key not in seen:
                seen.add(op.size_key)
                train_ops.append(op)
    test_g = build_paper_graph("alexnet")
    seen = set()
    test_ops = [op for op in test_g.ops.values()
                if op.size_key not in seen and not seen.add(op.size_key)]
    suite = RegressionSuite(feature_fn=MACHINE.counters, oracle=oracle,
                            cases=[1, 9, 17, 25, 33])
    rows = []
    for name in ("GradientBoosting", "KNeighbors", "TSR", "OLS", "PAR"):
        t0 = time.perf_counter()
        res = suite.evaluate(train_ops, test_ops, n_samples=4,
                             regressor=name)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(f"table4/{name},{dt:.0f},"
                    f"accuracy={res['accuracy']:.3f};r2={res['r2']:.3f}")
    return rows


def table5_hillclimb_accuracy() -> list[str]:
    """Table V: hill-climb prediction accuracy vs probe interval x."""
    oracle = _oracle(MACHINE)
    rows = []
    for model in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(model)
        for x in (2, 4, 8, 16):
            t0 = time.perf_counter()
            prof = HillClimbProfiler(oracle, paper_case_lists(), interval=x)
            store = prof.profile_graph(g)
            acc = float(np.mean([store.prediction_accuracy(op, oracle)
                                 for op in g.ops.values()]))
            dt = (time.perf_counter() - t0) * 1e6
            rows.append(f"table5/{model}/x{x},{dt:.0f},"
                        f"accuracy={acc:.4f};probes={store.total_probes}")
    return rows


def table6_per_op_speedup() -> list[str]:
    """Table VI: per-op-class time, recommendation vs Strategies 1-2."""
    rows = []
    for model in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(model)
        rec = uniform_schedule(g, MACHINE, intra=68, inter=1)
        rt = ConcurrencyRuntime(config=RuntimeConfig(enable_s3=False,
                                                     enable_s4=False))
        rt.profile(g)
        ours = rt.execute_step(g)
        rec_t = rec.per_class_time()
        our_t = ours.per_class_time()
        top = sorted(rec_t.items(), key=lambda kv: -kv[1])[:5]
        for cls, t_rec in top:
            t_our = our_t.get(cls, t_rec)
            rows.append(f"table6/{model}/{cls},{t_our*1e6:.1f},"
                        f"speedup_vs_rec={t_rec/max(t_our,1e-12):.2f}")
    return rows


def fig3_strategy_ablation() -> list[str]:
    """Fig 3: cumulative strategy contributions + vs manual tuning."""
    rows = []
    for model in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(model)
        base = uniform_schedule(g, MACHINE, intra=68, inter=1).makespan

        def run(s3, s4):
            rt = ConcurrencyRuntime(config=RuntimeConfig(
                enable_s3=s3, enable_s4=s4))
            rt.profile(g)
            return rt.execute_step(g).makespan

        s12 = run(False, False)
        s123 = run(True, False)
        s1234 = run(True, True)
        manual, cfg = manual_best_schedule(g, MACHINE)
        rows += [
            f"fig3/{model}/recommendation,{base*1e6:.0f},speedup=1.00",
            f"fig3/{model}/S1+S2,{s12*1e6:.0f},speedup={base/s12:.2f}",
            f"fig3/{model}/S1-3,{s123*1e6:.0f},speedup={base/s123:.2f}",
            f"fig3/{model}/S1-4,{s1234*1e6:.0f},speedup={base/s1234:.2f}",
            f"fig3/{model}/manual{cfg},{manual.makespan*1e6:.0f},"
            f"speedup={base/manual.makespan:.2f}",
        ]
    return rows


def fig4_corun_events() -> list[str]:
    """Fig 4: co-running op count, with and without Strategy 4."""
    rows = []
    for model in ("resnet50", "dcgan", "inception_v3"):
        g = build_paper_graph(model)
        for s4 in (False, True):
            rt = ConcurrencyRuntime(config=RuntimeConfig(enable_s4=s4))
            rt.profile(g)
            res = rt.execute_step(g)
            peak = max(n for _, n in res.events)
            rows.append(
                f"fig4/{model}/{'S3+S4' if s4 else 'S3only'},"
                f"{res.makespan*1e6:.0f},"
                f"mean_corun={res.mean_corunning:.2f};peak={peak}")
    return rows


ALL = [fig1_scaling_curves, table1_concurrency_grid, table2_input_size, table3_corun,
       table4_regression_accuracy, table5_hillclimb_accuracy,
       table6_per_op_speedup, fig3_strategy_ablation, fig4_corun_events]
