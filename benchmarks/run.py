# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from benchmarks import kernel_bench, multitenant_bench, paper_tables, \
        roofline
    fns = (list(paper_tables.ALL) + list(kernel_bench.ALL)
           + list(roofline.ALL) + list(multitenant_bench.ALL))
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in fns:
        if only and only not in fn.__name__:
            continue
        try:
            for row in fn():
                print(row)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{fn.__name__},0,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == '__main__':
    main()
