# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# ``--check-parity`` additionally runs the pool-vs-corun differential on
# the bench mix models and FAILS the run on any timeline divergence
# (including the traced leg, so tracing stays bit-for-bit inert), so
# perf runs double as strategy-core regression checks.
#
# ``--trace-out PATH`` runs the fully-armed 4-job mix with decision
# tracing enabled and writes the timeline as Chrome-trace/Perfetto JSON
# (open at https://ui.perfetto.dev) — the nightly lane uploads it as a
# CI artifact.
import sys
import traceback


def run_parity_check() -> None:
    """Print one mt/parity/<model> row per bench-mix model; exit nonzero
    on any timeline divergence (rows are printed BEFORE raising so CI
    logs always carry the per-model status)."""
    from benchmarks.multitenant_bench import MIX
    from repro.multitenant import check_parity

    report = check_parity([m for m, _ in MIX])
    for model, rec in report["models"].items():
        status = ("ok" if rec["ok"]
                  else f"DIVERGED:{rec['divergences'][0]}")
        print(f"mt/parity/{model},{rec['makespan']*1e6:.1f},{status}")
    if not report["ok"]:
        for model, rec in report["models"].items():
            for d in rec["divergences"][:10]:
                print(f"# parity divergence [{model}]: {d}",
                      file=sys.stderr)
        raise SystemExit("pool-vs-corun parity check FAILED")


def main() -> None:
    from benchmarks import cluster_bench, dynamic_bench, economics_bench, \
        feedback_bench, kernel_bench, multitenant_bench, numa_bench, \
        paper_tables, preemption_bench, roofline
    fns = (list(paper_tables.ALL) + list(kernel_bench.ALL)
           + list(roofline.ALL) + list(multitenant_bench.ALL)
           + list(preemption_bench.ALL) + list(economics_bench.ALL)
           + list(numa_bench.ALL) + list(feedback_bench.ALL)
           + list(dynamic_bench.ALL) + list(cluster_bench.ALL))
    argv = sys.argv[1:]
    trace_out = None
    if "--trace-out" in argv:
        i = argv.index("--trace-out")
        if i + 1 >= len(argv):
            raise SystemExit("--trace-out requires a PATH argument")
        trace_out = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    args = [a for a in argv if a != "--check-parity"]
    parity = "--check-parity" in argv
    only = args[0] if args else None
    print("name,us_per_call,derived")
    if parity:
        run_parity_check()
        if only is None and trace_out is None:
            # bare --check-parity = the cheap flat-topology differential
            # smoke (PR fast lane): parity rows only, no benches
            return
    if trace_out is not None:
        for row in multitenant_bench.export_mix_trace(trace_out):
            print(row)
        if only is None:
            return
    for fn in fns:
        if only and only not in fn.__name__:
            continue
        try:
            for row in fn():
                print(row)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{fn.__name__},0,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == '__main__':
    main()
