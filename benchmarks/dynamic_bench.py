"""Dynamic-control-flow benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Scenario: two loop populations with OPPOSITE truths, so each frozen
pricing is right about one and badly wrong about the other — only online
trip-count estimation prices both correctly:

* **serving waves** exit far earlier than their predicate bound (2 of 12
  decoder layers): pessimistic (``est_trips = max_trips``) pricing books
  6x the real footprint against the admission cap, so consecutive waves
  serialize behind fictional capacity and the queue grows for the whole
  burst;
* **fillers** are low-priority batch loops that genuinely run to their
  bound (8 of 8 trips): optimistic (``est_trips = 1``) pricing books a
  fraction of their true footprint, so the cap happily admits a fleet of
  machine-hogs right into the serving window and wave latency drowns in
  contention.

The ewma leg starts from the pessimistic prior, learns the wave's region
keys from a deadline-free teacher wave that resolves before the burst
begins, and then prices both populations right: waves admit immediately
(their booking is the observed two layers) AND fillers stay priced out
of the serving window (their max-trip prior IS their truth).  A small
recurrent-trainer mix rides along as the throughput probe — its loops
exercise the region machinery in every leg, while its demand is sized so
admission pricing can never delay it and its completion time isolates
pure machine contention.

Claims measured:

* ``dynamic_deadline_tail`` — wave deadline p95 under ewma strictly
  beats both frozen pricings, and the estimator genuinely learned (its
  decoder estimate lands on the observed depth, not the prior).
* ``dynamic_throughput_held`` — trainer-mix throughput under ewma stays
  within 3% of the best frozen leg, and every leg exercised the region
  machinery (events agree with the result counters).
"""

from __future__ import annotations

import numpy as np

from repro.core import PreemptionPolicy, RuntimeConfig, SimMachine
from repro.core.graph import build_early_exit_wave, build_recurrent_step_graph
from repro.multitenant import PoolConfig, RuntimePool
from repro.obs import FAM_REGION, RecordingSink

MACHINE = SimMachine()

N_TRAINERS = 3
TRAINER_TRIPS = 6         # actual trips; max_trips=12 prices 2x pessimist
TRAINER_MAX = 12
TRAINER_SHAPE = (16, 16, 64)  # small on purpose: the trainers are the
TRAINER_WORK = 120.0          # throughput mix, not cap contestants — at
                              # this size even the 2x pessimistic booking
                              # is a rounding error against the cap, so
                              # trainer completion times isolate MACHINE
                              # contention (fillers admitted or not),
                              # which is the cost being measured
TRAINER_STAGGER = 0.0016
N_WAVES = 10
WAVE_DEPTH = 2            # actual decoder layers; max_depth=12 makes the
WAVE_MAX = 12             # pessimistic booking 6x the real footprint
WAVE_WORK = 320.0
WAVE_START = 0.0045       # stream begins once the teacher has resolved
WAVE_GAP = 0.0012
WAVE_BUDGET = 0.0025      # per-wave latency budget (solo wave ~1.9ms)
N_FILLERS = 6
FILLER_TRIPS = 8          # runs to its bound: OPTIMISTIC pricing is the
FILLER_MAX = 8            # wrong one here
FILLER_SHAPE = (64, 32, 128)
FILLER_WORK = 500.0
FILLER_START = 0.0055     # inside the wave window: waves already hold
FILLER_GAP = 0.0015       # cap share, so honest filler pricing queues
                          # the fleet behind the serving burst
DEMAND_CAP = 0.14         # core-seconds of outstanding admitted demand:
                          # sized to the mix's ACTUAL footprint (two
                          # co-resident trainers at their observed trip
                          # count plus waves), so worst-case pricing
                          # starves wave admission and 1-trip pricing
                          # lets the filler fleet in

_RESULTS = None


def _est(kind: str, max_trips: float) -> float:
    """The leg's trip prior: pessimistic and the ewma STARTING point are
    the predicate bound; optimistic is one trip."""
    return 1.0 if kind == "opt" else float(max_trips)


def _run_leg(kind: str):
    """One pool run: kind is "pess" | "opt" | "ewma"."""
    feedback = "ewma" if kind == "ewma" else "off"
    sink = RecordingSink()
    pool = RuntimePool(machine=MACHINE, config=PoolConfig(
        max_active=12, max_outstanding_demand=DEMAND_CAP, sink=sink,
        preemption=PreemptionPolicy(enabled=True),
        runtime=RuntimeConfig(feedback=feedback)))
    trainers = [pool.submit(
        build_recurrent_step_graph(trips=TRAINER_TRIPS,
                                   max_trips=TRAINER_MAX,
                                   est_trips=_est(kind, TRAINER_MAX),
                                   shape=TRAINER_SHAPE, work=TRAINER_WORK,
                                   name=f"trainer{i}"),
        name=f"trainer-{i}",
        submit_time=0.0 if i == 0 else TRAINER_STAGGER)
        for i in range(N_TRAINERS)]
    # the teacher: same loop/branch keys as the waves, no deadline — its
    # resolution is what seeds the ewma leg's trip-count estimator
    pool.submit(build_early_exit_wave(
        depth=WAVE_DEPTH, max_depth=WAVE_MAX,
        est_depth=_est(kind, WAVE_MAX), work=WAVE_WORK,
        accept=True, name="teacher"), name="teacher")
    for f in range(N_FILLERS):
        pool.submit(build_recurrent_step_graph(
            trips=FILLER_TRIPS, max_trips=FILLER_MAX,
            est_trips=_est(kind, FILLER_MAX), shape=FILLER_SHAPE,
            work=FILLER_WORK, name=f"filler{f}"),
            name=f"filler-{f}", priority=0.5,
            submit_time=FILLER_START + f * FILLER_GAP)
    waves = []
    for w in range(N_WAVES):
        t = WAVE_START + w * WAVE_GAP
        waves.append(pool.submit(
            build_early_exit_wave(depth=WAVE_DEPTH, max_depth=WAVE_MAX,
                                  est_depth=_est(kind, WAVE_MAX),
                                  work=WAVE_WORK, accept=True,
                                  name=f"wave{w}"),
            name=f"wave-{w}", priority=4.0, submit_time=t,
            deadline=t + WAVE_BUDGET))
    res = pool.run()
    lats = sorted(j.latency for j in waves)
    waits = sorted(j.queue_wait for j in waves)
    mix_finish = max(j.finish_time for j in trainers)
    mix_ops = sum(len(res.records[j.jid]) for j in trainers)
    return {
        "result": res,
        "pool": pool,
        "p50": float(np.percentile(lats, 50)),
        "p95": float(np.percentile(lats, 95)),
        "wait_p95": float(np.percentile(waits, 95)),
        "hit_rate": sum(1 for x in lats if x <= WAVE_BUDGET) / len(lats),
        "mix_throughput": mix_ops / mix_finish,
        "region_events": len(sink.by_family(FAM_REGION)),
    }


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = {k: _run_leg(k) for k in ("pess", "opt", "ewma")}
    return _RESULTS


def dynamic_deadline_tail() -> list[str]:
    r = _results()
    rows = []
    for k in ("pess", "opt", "ewma"):
        rows.append(
            f"mt/dyn_wave_p95_{k},{r[k]['p95']*1e6:.1f},"
            f"p50={r[k]['p50']*1e6:.1f}us"
            f" hit={r[k]['hit_rate']:.2f}"
            f" wait_p95={r[k]['wait_p95']*1e6:.1f}us")
    est = r["ewma"]["pool"].trip_counts
    depth_key = ("while", "decoder_layer", (16, 64, 96))
    learned = est.estimate(depth_key, float(WAVE_MAX))
    rows.append(f"mt/dyn_learned_depth,{learned:.2f},"
                f"actual={WAVE_DEPTH} prior={WAVE_MAX}")
    assert r["ewma"]["p95"] < r["pess"]["p95"], \
        "ewma trip-count pricing must beat pessimistic (max-trip) " \
        f"frozen pricing on deadline p95 ({r['ewma']['p95']:.6f} vs " \
        f"{r['pess']['p95']:.6f})"
    assert r["ewma"]["p95"] < r["opt"]["p95"], \
        "ewma trip-count pricing must beat optimistic (1-trip) frozen " \
        f"pricing on deadline p95 ({r['ewma']['p95']:.6f} vs " \
        f"{r['opt']['p95']:.6f})"
    assert est.observed > 0 and abs(learned - WAVE_DEPTH) <= 1.0, \
        f"estimator never converged on the observed depth: {learned}"
    return rows


def dynamic_throughput_held() -> list[str]:
    r = _results()
    best_frozen = max(r["pess"]["mix_throughput"],
                      r["opt"]["mix_throughput"])
    ratio = r["ewma"]["mix_throughput"] / best_frozen
    rows = [
        f"mt/dyn_mix_thpt_pess,0,{r['pess']['mix_throughput']:.1f}ops/s",
        f"mt/dyn_mix_thpt_opt,0,{r['opt']['mix_throughput']:.1f}ops/s",
        f"mt/dyn_mix_thpt_ewma,0,{r['ewma']['mix_throughput']:.1f}ops/s",
        f"mt/dyn_mix_thpt_ratio,0,{ratio:.3f}",
    ]
    for k in ("pess", "opt", "ewma"):
        res = r[k]["result"]
        rows.append(f"mt/dyn_regions_{k},{res.n_region_expands},"
                    f"resolves={res.n_region_resolves}"
                    f" traced={r[k]['region_events']}")
        assert res.n_region_expands > 0 and res.n_region_resolves > 0, \
            f"leg {k} never exercised the region machinery"
        assert r[k]["region_events"] == \
            res.n_region_expands + res.n_region_resolves, \
            f"leg {k}: traced region events disagree with counters"
    assert ratio >= 0.97, \
        f"trip-count learning costs >3% mix throughput ({ratio:.3f})"
    return rows


ALL = [dynamic_deadline_tail, dynamic_throughput_held]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
