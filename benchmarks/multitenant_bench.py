"""Multi-tenant pool benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Claims measured:

* ``pool_vs_serial`` — aggregate throughput of the co-scheduling pool is
  strictly higher than running the same job mix one graph at a time.
* ``pool_fairness_latency`` — per-job latency and the Jain fairness index
  of the weighted-fair-share policy under mixed priorities.
* ``plancache_amortization`` — the shared PlanCache cuts total profiling
  probes across tenants versus isolated per-job profiling.
* ``serving_corun_training`` — a high-priority serving wave co-scheduled
  with a training step finishes far sooner than queued behind it
  (latency, not makespan, is the claim: co-running a tiny wave next to a
  big step pays a little bandwidth contention but stops head-of-line
  blocking).  The serial baseline is priority-blind FIFO by design (see
  ``RuntimePool.run_serial``) — priority queueing is itself a pool
  feature, so this number credits co-scheduling + priority together.
"""

from __future__ import annotations

from repro.core import SimMachine, build_paper_graph
from repro.multitenant import PoolConfig, RuntimePool

MACHINE = SimMachine()

MIX = [("resnet50", 1.0), ("dcgan", 1.0), ("resnet50", 2.0), ("dcgan", 1.0)]

_MIX_RESULTS = None


def _mix_results():
    """One shared (pool result, serial result) pair — the mix run is
    deterministic, and three bench functions report different slices of
    the same run."""
    global _MIX_RESULTS
    if _MIX_RESULTS is None:
        pool = RuntimePool(machine=MACHINE,
                           config=PoolConfig(max_active=3))
        for i, (model, prio) in enumerate(MIX):
            pool.submit(build_paper_graph(model), priority=prio,
                        name=f"{model}-{i}")
        _MIX_RESULTS = (pool.run(), pool.run_serial())
    return _MIX_RESULTS


def pool_vs_serial() -> list[str]:
    res, serial = _mix_results()
    rows = [
        f"mt/pool_makespan,{res.makespan*1e6:.1f},"
        f"thpt={res.aggregate_throughput:.1f}ops/s",
        f"mt/serial_makespan,{serial.makespan*1e6:.1f},"
        f"thpt={serial.aggregate_throughput:.1f}ops/s",
        f"mt/aggregate_speedup,{res.makespan*1e6:.1f},"
        f"speedup={serial.makespan/res.makespan:.3f}x",
    ]
    assert res.aggregate_throughput > serial.aggregate_throughput, \
        "pool must beat serial aggregate throughput"
    return rows


def pool_fairness_latency() -> list[str]:
    res, serial = _mix_results()
    # service-based Jain reflects the mix's demand skew; slowdown-based
    # Jain (latency vs running alone) reflects what the scheduler did.
    # Two slowdown variants: e2e divides submit-to-finish by the solo
    # makespan (charges the scheduler for admission queueing), sched
    # divides admit-to-finish (isolates the core scheduler — a job that
    # merely waited in the admission queue is not unfair scheduling).
    sched_jain = res.slowdown_fairness(serial.job_makespans,
                                       include_queue_wait=False)
    rows = [
        f"mt/fairness,0,jain={res.fairness:.3f}",
        f"mt/slowdown_fairness_e2e,0,"
        f"jain={res.slowdown_fairness(serial.job_makespans):.3f}",
        f"mt/slowdown_fairness_sched,0,jain={sched_jain:.3f}",
    ]
    for j in res.jobs:
        rows.append(
            f"mt/latency/{j.name},{j.latency*1e6:.1f},"
            f"serial={serial.job_latencies[j.jid]*1e6:.1f}us")
    return rows


def plancache_amortization() -> list[str]:
    res, serial = _mix_results()      # serial = per-job isolated profiling
    # read through the metrics registry (``PoolResult.metrics``), not the
    # raw cache_stats dict: the bench doubles as a consumer check on the
    # cache.* gauges the registry publishes
    spent = res.metrics["cache.probes_spent"]
    saved = res.metrics["cache.probes_saved"]
    rows = [
        f"mt/plancache_probes,{spent:.0f},"
        f"isolated={serial.profiling_probes}",
        f"mt/plancache_saved,{saved:.0f},"
        f"hit_rate={res.metrics['cache.hit_rate']:.2f}",
    ]
    assert spent == res.cache_stats["probes_spent"], \
        "cache.* gauges must mirror PlanCache.stats()"
    assert spent < serial.profiling_probes, \
        "shared PlanCache must reduce total profiling probes"
    return rows


def export_mix_trace(path: str = "pool_trace.json") -> list[str]:
    """Run a fully-armed 4-job mix traced end-to-end and write the
    timeline as Chrome-trace/Perfetto JSON (open at ui.perfetto.dev).

    The mix is configured so every decision family fires: quadrant
    topology (placement bookings), ewma feedback (plan-store updates),
    staggered arrivals + a demand cap under ``max_active=2`` (admission
    defers), and tight deadlines with preemption armed (revocations).
    Asserts every event family a single-machine static mix can fire
    actually appears, so the CI artifact can't silently degrade into a
    partial trace."""
    from repro.multitenant import PreemptionPolicy
    from repro.obs import (FAM_CLUSTER, FAMILIES, RecordingSink,
                           export_pool_trace)

    sink = RecordingSink()
    pool = RuntimePool(
        machine=SimMachine(),
        config=PoolConfig(max_active=2, topology="quadrant",
                          feedback="ewma",
                          max_outstanding_demand=5000.0,
                          preemption=PreemptionPolicy(enabled=True),
                          sink=sink))
    for i, (model, prio) in enumerate(MIX):
        submit = i * 0.0005
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}", submit_time=submit,
                    deadline=(submit + 0.002 if i % 2 else None))
    res = pool.run()
    trace = export_pool_trace(res, path, sink.events)
    # cluster events need a second machine; a single-machine pool run
    # can never fire them (positive coverage: cluster_bench + the
    # FAM_CLUSTER tests in tests/test_cluster.py)
    missing = [f for f in FAMILIES
               if f != FAM_CLUSTER and f not in sink.families()]
    assert not missing, \
        f"trace mix must exercise every decision family, missing {missing}"
    return [
        f"mt/trace_decision_events,{len(sink.events)},"
        f"families={len(sink.families())}",
        f"mt/trace_perfetto_events,{len(trace['traceEvents'])},"
        f"path={path}",
    ]


def serving_corun_training() -> list[str]:
    """A serving tenant (wave graph) next to a training tenant."""
    import numpy as np

    from repro.configs import get_config
    from repro.serving import Request, wave_op_graph

    cfg = get_config("olmo-1b", smoke=True)
    rng = np.random.default_rng(0)
    wave = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=12).astype(
                        np.int32),
                    max_new_tokens=16) for i in range(4)]
    pool = RuntimePool(machine=MACHINE, config=PoolConfig(max_active=2))
    pool.submit(build_paper_graph("resnet50"), name="train-step")
    pool.submit(wave_op_graph(cfg, wave), priority=2.0,
                name="serve-wave")
    res = pool.run()
    serial = pool.run_serial()
    serve = next(j for j in res.jobs if j.name == "serve-wave")
    rows = [
        f"mt/serve+train_pool,{res.makespan*1e6:.1f},"
        f"speedup={serial.makespan/res.makespan:.3f}x",
        f"mt/serve_wave_latency,{serve.latency*1e6:.1f},"
        f"serial={serial.job_latencies[serve.jid]*1e6:.1f}us",
    ]
    assert serve.latency < serial.job_latencies[serve.jid], \
        "co-scheduled wave must beat its serial queue position"
    return rows


ALL = [pool_vs_serial, pool_fairness_latency, plancache_amortization,
       serving_corun_training]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
