"""Preemption-economics benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Scenario: four narrow training runners (17-thread RunnerOp chains that
tile the 68-core machine exactly four-across) plus a stream of wide
deadlined tenants (68-thread WideStep chains, ~0.28s/op solo) arriving
far enough apart that each meets a fully retiled machine.  A
single-victim preemption pool can only revoke ONE 17-thread runner per
overdue waiter, so the wide op squeezes into a fraction of the machine;
the economics pool assembles a cheapest-summed-waste victim SET, evicts
launch-free admitted jobs for free, and re-seats squeezed ops at full
width when the priced gain beats the re-billed restart waste.

Claims measured:

* ``economics_tail_latency`` — p50/p95 submit-to-finish latency of the
  wide deadlined tenants improves strictly over the single-victim pool,
  and at least one multi-victim revoke (or free eviction) actually
  fired, priced gain > summed waste.
* ``economics_throughput_held`` — aggregate throughput on the 4-runner
  training mix stays within 3% of the single-victim pool (the extra
  revoked partials are real waste, bounded by the pricing guard), and
  every width migration the run emitted was priced gain > cost.
"""

from __future__ import annotations

import numpy as np

from repro.core import GraphBuilder, SimMachine
from repro.multitenant import PoolConfig, PreemptionPolicy, RuntimePool
from repro.obs import RecordingSink

MACHINE = SimMachine()

N_RUNNERS = 4             # 17 threads each: tiles 68 cores exactly
RUNNER_CHAIN = 4          # ~2.7s per RunnerOp; keeps the machine packed
N_WIDE = 5
WIDE_GAP = 1.7            # seconds between wide-tenant arrivals: each one
                          # meets a retiled machine (runners restarted
                          # after the previous revoke), so every arrival
                          # re-exercises the multi-victim decision
WIDE_BUDGET = 0.1         # per-tenant latency budget (solo wide chain is
                          # ~0.56s: always overdue on arrival, the
                          # must-preempt regime)

_RESULTS = None


def _chain(name: str, op_class: str, shape, flops: float, bw: float,
           pf: float, n: int):
    b = GraphBuilder(name)
    prev = None
    for _ in range(n):
        prev = b.add(op_class, shape, flops=flops, bytes_moved=bw,
                     working_set=bw, parallel_fraction=pf,
                     deps=[prev] if prev is not None else [])
    return b.build()


def _run_pool(policy: PreemptionPolicy):
    sink = RecordingSink()
    pool = RuntimePool(
        machine=MACHINE,
        config=PoolConfig(
            max_active=8,       # admission is not the effect under test:
                                # every tenant is admitted so the latency
                                # gap isolates the victim-set economics
            sink=sink,
            preemption=policy))
    mix = [pool.submit(_chain(f"runner{i}", "RunnerOp", (48, 96, 64),
                              8e11, 4e7, 0.96, RUNNER_CHAIN),
                       name=f"runner-{i}")
           for i in range(N_RUNNERS)]
    wides = []
    for w in range(N_WIDE):
        t = 0.05 + w * WIDE_GAP
        wides.append(pool.submit(
            _chain(f"wide{w}", "WideStep", (256, 256, 64), 4e11, 5e7,
                   0.99, 2),
            name=f"wide-{w}", priority=4.0, submit_time=t,
            deadline=t + WIDE_BUDGET))
    res = pool.run()
    lats = sorted(j.latency for j in wides)
    mix_finish = max(j.finish_time for j in mix)
    mix_ops = sum(len(res.records[j.jid]) for j in mix)
    return {
        "result": res,
        "p50": float(np.percentile(lats, 50)),
        "p95": float(np.percentile(lats, 95)),
        "mix_throughput": mix_ops / mix_finish,
        "events": [e for e in sink.events if e.family == "preemption"],
    }


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = (
            _run_pool(PreemptionPolicy(enabled=True)),
            _run_pool(PreemptionPolicy(enabled=True, max_victims=4,
                                       evict_admitted=True,
                                       migration=True)),
        )
    return _RESULTS


def economics_tail_latency() -> list[str]:
    single, econ = _results()
    multi = [e for e in econ["events"] if e.kind == "multi_revoke"]
    evictions = [e for e in econ["events"] if e.kind == "evict"]
    rows = [
        f"mt/econ_wide_p50_single,{single['p50']*1e6:.1f},budget="
        f"{WIDE_BUDGET*1e6:.0f}us",
        f"mt/econ_wide_p50_econ,{econ['p50']*1e6:.1f},"
        f"speedup={single['p50']/max(econ['p50'],1e-12):.2f}x",
        f"mt/econ_wide_p95_single,{single['p95']*1e6:.1f},budget="
        f"{WIDE_BUDGET*1e6:.0f}us",
        f"mt/econ_wide_p95_econ,{econ['p95']*1e6:.1f},"
        f"speedup={single['p95']/max(econ['p95'],1e-12):.2f}x",
        f"mt/econ_multi_revokes,{len(multi)},evictions={len(evictions)}",
    ]
    assert econ["p95"] < single["p95"], \
        "victim-set economics must improve wide-tenant p95 over " \
        "single-victim preemption"
    assert multi or evictions, \
        "scenario must actually exercise a multi-victim revoke or an " \
        "admission-level eviction"
    for e in multi:
        assert e.data["gain"] > e.data["waste"], \
            f"multi-victim revoke priced at a loss: {e.data}"
    assert all(e.data.get("set_size", 1) == 1
               for e in single["events"] if e.kind == "revoke"), \
        "single-victim pool must never revoke a set"
    return rows


def economics_throughput_held() -> list[str]:
    single, econ = _results()
    ratio = econ["mix_throughput"] / single["mix_throughput"]
    migrates = [e for e in econ["events"] if e.kind == "migrate"]
    rows = [
        f"mt/econ_mix_thpt_single,0,{single['mix_throughput']:.1f}ops/s",
        f"mt/econ_mix_thpt_econ,0,{econ['mix_throughput']:.1f}ops/s",
        f"mt/econ_mix_thpt_ratio,0,{ratio:.3f}",
        f"mt/econ_migrations,{econ['result'].n_migrations},"
        f"priced_events={len(migrates)}",
    ]
    assert ratio >= 0.97, \
        f"economics cost on mix throughput exceeds 3% ({ratio:.3f})"
    # every width migration must have been priced: predicted-remaining
    # gain strictly above the re-billed restart waste (vacuous when the
    # run emitted none — the pricing guard, not the move, is the claim)
    for e in migrates:
        assert e.data["gain"] > e.data["cost"], \
            f"width migration priced at a loss: {e.data}"
    assert single["result"].n_evictions == 0 \
        and single["result"].n_migrations == 0, \
        "single-victim pool must not take economics moves"
    return rows


ALL = [economics_tail_latency, economics_throughput_held]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
