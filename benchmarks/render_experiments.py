"""Merge dry-run JSONs and render the EXPERIMENTS.md §Roofline table.

Usage: PYTHONPATH=src python benchmarks/render_experiments.py
Merges experiments/dryrun*.json (later files override earlier records for
the same (arch, shape, mesh)), writes experiments/dryrun_merged.json and
prints the markdown table (also appended to EXPERIMENTS.md if --write).
"""

from __future__ import annotations

import glob
import json
import os
import sys

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")

ARCH_ORDER = ["granite-3-8b", "llama3-405b", "codeqwen1.5-7b", "olmo-1b",
              "llama4-scout-17b-a16e", "mixtral-8x7b", "rwkv6-1.6b",
              "llama-3.2-vision-11b", "recurrentgemma-2b", "whisper-small"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def merge() -> dict:
    records: dict[tuple, dict] = {}
    files = sorted(glob.glob(os.path.join(EXP_DIR, "dryrun*.json")))
    files = [f for f in files if "merged" not in f]
    for path in files:
        with open(path) as f:
            for r in json.load(f):
                key = (r.get("arch"), r.get("shape"),
                       r.get("mesh_name", r.get("mesh")))
                # prefer non-error records from later files
                if key in records and "error" in r \
                        and "error" not in records[key]:
                    continue
                records[key] = r
    return records


def fmt(v, digits=3):
    return f"{v:.{digits}f}" if isinstance(v, (int, float)) else "-"


def table(records: dict) -> str:
    lines = [
        "| arch | shape | dom | compute_s | memory_s (est/hlo) | "
        "collective_s | useful | HBM GiB/dev | fits | multi-pod |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = records.get((arch, shape, "single"))
            m = records.get((arch, shape, "multi"))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | skip | - | - | - | - | "
                             f"- | - | - |")
                continue
            if "error" in r:
                lines.append(f"| {arch} | {shape} | ERROR | - | - | - | - |"
                             f" - | - | - |")
                continue
            rf = r["roofline"]
            hbm = r.get("hbm_bytes_per_device_est", 0) / 2**30
            multi = "-"
            if m is not None and "error" not in m and "skipped" not in m:
                multi = "ok" + ("+fits" if m.get("fits_hbm") else "")
            lines.append(
                f"| {arch} | {shape} | {rf['dominant'][:4]} "
                f"| {fmt(rf['compute_s'])} "
                f"| {fmt(rf['memory_s'])}/{fmt(rf.get('memory_s_hlo'))} "
                f"| {fmt(rf['collective_s'])} "
                f"| {fmt(r.get('useful_flops_ratio'))} "
                f"| {hbm:.1f} | {r.get('fits_hbm')} | {multi} |")
    return "\n".join(lines)


def summary(records: dict) -> str:
    n_ok = sum(1 for r in records.values()
               if "error" not in r and "skipped" not in r)
    n_skip = sum(1 for r in records.values() if "skipped" in r)
    n_err = sum(1 for r in records.values() if "error" in r)
    multi_ok = sum(1 for (a, s, mname), r in records.items()
                   if mname == "multi" and "error" not in r
                   and "skipped" not in r)
    return (f"cells: {n_ok} compiled ok, {n_skip} skipped (documented), "
            f"{n_err} errors; multi-pod compiles ok: {multi_ok}")


def main() -> None:
    records = merge()
    out = os.path.join(EXP_DIR, "dryrun_merged.json")
    with open(out, "w") as f:
        json.dump([{"key": list(k), **v} for k, v in records.items()], f,
                  indent=1)
    tbl = table(records)
    summ = summary(records)
    print(summ)
    print(tbl)
    if "--write" in sys.argv:
        exp = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
        with open(exp) as f:
            text = f.read()
        marker = "## §Roofline table (rendered from experiments/dryrun.json)"
        head = text.split(marker)[0]
        with open(exp, "w") as f:
            f.write(head + marker + "\n\n" + summ + "\n\n" + tbl + "\n")
        print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
