"""Deadline-aware preemption benchmarks (one function per headline claim).

Row convention matches benchmarks/run.py: ``name,us_per_call,derived``.

Scenario: the PR-1/PR-2 4-job training mix plus a stream of high-priority
serving waves with a latency target, run twice through identically
configured pools — preemption OFF (the PR-2 pool) and preemption ON
(deadline slack armed through ``ServeEngine``-style wave deadlines).

Claims measured:

* ``preemption_tail_latency`` — p50/p95 submit-to-finish latency of the
  high-priority waves improves with preemption on (the head-of-line op a
  wave used to queue behind is revoked once the wave's slack runs out).
* ``preemption_throughput_held`` — aggregate throughput on the 4-job
  training mix stays within 5% of the preemption-off pool (the revoked
  partial work is real waste, bounded by the victim-advantage guard), and
  the deadline-free mix itself is scheduled bit-for-bit identically, so
  the PR-2 headline speedup (1.74x serial) is untouched.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import SimMachine, build_paper_graph
from repro.multitenant import PoolConfig, PreemptionPolicy, RuntimePool
from repro.serving import Request, wave_op_graph

MACHINE = SimMachine()

MIX = [("resnet50", 1.0), ("dcgan", 1.0), ("resnet50", 2.0), ("dcgan", 1.0)]

N_WAVES = 12
WAVE_GAP = 0.008          # seconds between wave arrivals
WAVE_TARGET = 0.0012      # per-wave latency SLO mapped to a pool deadline
                          # (solo wave critical path is ~1.16ms: feasible
                          # when granted cores promptly, blown when queued
                          # behind a multi-ms training op — the preemption
                          # trigger regime)

_RESULTS = None


def _wave_graphs():
    cfg = get_config("olmo-1b", smoke=True)
    rng = np.random.default_rng(0)
    graphs = []
    for w in range(N_WAVES):
        wave = [Request(rid=w * 4 + i,
                        prompt=rng.integers(0, cfg.vocab, size=12).astype(
                            np.int32),
                        max_new_tokens=8) for i in range(4)]
        graphs.append(wave_op_graph(cfg, wave, n_slots=4,
                                    name=f"serve-wave{w}"))
    return graphs


def _run_pool(preempt: bool):
    pool = RuntimePool(
        machine=MACHINE,
        config=PoolConfig(
            max_active=8,       # admission is not the effect under test:
                                # every tenant is admitted so the latency
                                # gap isolates op-level (non-)preemption
            preemption=PreemptionPolicy(enabled=True) if preempt else None))
    for i, (model, prio) in enumerate(MIX):
        pool.submit(build_paper_graph(model), priority=prio,
                    name=f"{model}-{i}")
    waves = []
    for w, g in enumerate(_wave_graphs()):
        t = w * WAVE_GAP
        waves.append(pool.submit(g, priority=4.0, name=g.name,
                                 submit_time=t, deadline=t + WAVE_TARGET))
    res = pool.run()
    lats = sorted(j.latency for j in waves)
    mix_jobs = [j for j in res.jobs if j.deadline is None]
    mix_finish = max(j.finish_time for j in mix_jobs)
    mix_ops = sum(len(res.records[j.jid]) for j in mix_jobs)
    return {
        "result": res,
        "p50": float(np.percentile(lats, 50)),
        "p95": float(np.percentile(lats, 95)),
        "mix_throughput": mix_ops / mix_finish,
    }


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = (_run_pool(False), _run_pool(True))
    return _RESULTS


def preemption_tail_latency() -> list[str]:
    off, on = _results()
    rows = [
        f"mt/preempt_wave_p50_off,{off['p50']*1e6:.1f},target="
        f"{WAVE_TARGET*1e6:.0f}us",
        f"mt/preempt_wave_p50_on,{on['p50']*1e6:.1f},"
        f"speedup={off['p50']/max(on['p50'],1e-12):.2f}x",
        f"mt/preempt_wave_p95_off,{off['p95']*1e6:.1f},target="
        f"{WAVE_TARGET*1e6:.0f}us",
        f"mt/preempt_wave_p95_on,{on['p95']*1e6:.1f},"
        f"speedup={off['p95']/max(on['p95'],1e-12):.2f}x",
        f"mt/preempt_count,{on['result'].n_preemptions},off="
        f"{off['result'].n_preemptions}",
    ]
    assert off["result"].n_preemptions == 0, \
        "preemption-off pool must never revoke a launch"
    assert on["result"].n_preemptions > 0, \
        "scenario must actually exercise preemption"
    assert on["p95"] < off["p95"], \
        "preemption must improve p95 high-priority wave latency"
    return rows


def preemption_throughput_held() -> list[str]:
    off, on = _results()
    ratio = on["mix_throughput"] / off["mix_throughput"]
    rows = [
        f"mt/preempt_mix_thpt_off,0,{off['mix_throughput']:.1f}ops/s",
        f"mt/preempt_mix_thpt_on,0,{on['mix_throughput']:.1f}ops/s",
        f"mt/preempt_mix_thpt_ratio,0,{ratio:.3f}",
    ]
    assert ratio >= 0.95, \
        f"preemption cost on mix throughput exceeds 5% ({ratio:.3f})"
    # tie back to the PR-2 headline: the deadline-free 4-job mix runs
    # bit-identically through a preemption-enabled pool (no deadlines =
    # no slack = nothing to preempt), so the 1.74x-serial aggregate
    # speedup is structurally untouched — reuse the multitenant bench's
    # cached mix run rather than re-running it
    from benchmarks.multitenant_bench import _mix_results
    res, serial = _mix_results()
    speedup = serial.makespan / res.makespan
    rows.append(f"mt/preempt_mix_alone_speedup,0,{speedup:.3f}x_serial")
    assert speedup >= 1.74 * 0.95, \
        f"4-job mix aggregate speedup regressed ({speedup:.3f}x serial)"
    return rows


ALL = [preemption_tail_latency, preemption_throughput_held]


if __name__ == "__main__":
    for fn in ALL:
        for row in fn():
            print(row)
